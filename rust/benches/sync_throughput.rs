//! Bench: the federation subsystem's hot paths, in records/second.
//!
//! * **Replay** — how fast a segment store recovers a corpus on
//!   startup, from the WAL (line-by-line op replay) and from a compacted
//!   snapshot (bulk CSV load + op-log sidecar). This bounds restart time
//!   for a durable coordinator service.
//! * **Sync** — how fast two peers holding disjoint org corpora
//!   converge through a full `Watermarks`/`SyncPull`/`SyncPush`
//!   exchange (both directions, merge-dedup applied). This bounds how
//!   quickly a fresh deployment catches up with the federation.
//! * **Incremental** — the record-level-delta payoff: after two peers
//!   converge, exactly **one** record changes. The v3 (op log) exchange
//!   must ship one op; the v2-equivalent org-granular exchange re-ships
//!   the whole changed org. The shipped-record ratio is asserted ≥ 10x
//!   and recorded in the JSON.
//! * **Batched** — the cross-job (v4) payoff: one
//!   `WatermarksAll`/`SyncPullAll`/`SyncPushAll` conversation covers all
//!   five job kinds, where the per-job v3 exchange pays round trips per
//!   kind. Batched round trips are asserted strictly fewer, full and
//!   idle.
//! * **Mesh** — roster-scheduled gossip: three peers converge through
//!   rotating-fanout [`mesh_round`]s with acked-floor truncation
//!   folding the op logs behind them.
//!
//! Model training is disabled (cold-start threshold maxed) so the
//! numbers measure persistence and exchange, not model selection.
//!
//! Emits `BENCH_sync_throughput.json`. Shrink with
//! `C3O_SYNC_RECORDS=500` for smoke runs.

use c3o::api::{ApiError, Client, MeshHello, MeshPeer};
use c3o::cloud::Cloud;
use c3o::coordinator::Coordinator;
use c3o::models::Engine;
use c3o::repo::{RuntimeDataRepo, RuntimeRecord};
use c3o::store::{
    mesh_peer, mesh_round, sync, JobStore, StoreOp, SyncOptions, SyncProtocol, SyncScope,
    SyncStats,
};
use c3o::util::json::Json;
use c3o::workloads::JobKind;
use std::path::PathBuf;
use std::time::Instant;

const MACHINES: [&str; 3] = ["c5.xlarge", "m5.xlarge", "r5.xlarge"];

/// One-job v3 exchange through the consolidated [`sync`] entry point.
fn sync_job(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    job: JobKind,
) -> Result<SyncStats, ApiError> {
    sync(
        local,
        peer,
        &SyncOptions {
            scope: SyncScope::Job(job),
            ..SyncOptions::default()
        },
    )
    .map(|summary| summary.stats)
}

/// One-job exchange over the legacy v2 org-granular protocol.
fn sync_job_v2(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    job: JobKind,
) -> Result<SyncStats, ApiError> {
    sync(
        local,
        peer,
        &SyncOptions {
            scope: SyncScope::Job(job),
            protocol: SyncProtocol::V2,
            ..SyncOptions::default()
        },
    )
    .map(|summary| summary.stats)
}

/// Multi-job v3 exchange, stats folded.
fn sync_all(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    jobs: &[JobKind],
) -> Result<SyncStats, ApiError> {
    sync(
        local,
        peer,
        &SyncOptions {
            scope: SyncScope::Jobs(jobs.to_vec()),
            ..SyncOptions::default()
        },
    )
    .map(|summary| summary.stats)
}

/// Synthetic sort records with globally-unique configurations.
fn synthetic_records(n: usize) -> Vec<RuntimeRecord> {
    (0..n)
        .map(|i| RuntimeRecord {
            job: JobKind::Sort,
            org: format!("org-{}", i % 7),
            machine: MACHINES[i % MACHINES.len()].to_string(),
            scaleout: 2 + (i % 14) as u32,
            job_features: vec![1.0 + 0.5 * i as f64],
            runtime_s: 50.0 + (i % 997) as f64,
        })
        .collect()
}

fn temp_root(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("c3o_syncbench_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn relabel(rs: &[RuntimeRecord], org: &str) -> Vec<RuntimeRecord> {
    rs.iter().map(|r| r.with_org(org)).collect()
}

/// A pair of no-training peers, each having shared one half of
/// `records` under its own org (not yet exchanged).
fn seeded_peers(cloud: &Cloud, records: &[RuntimeRecord]) -> (Coordinator, Coordinator) {
    let half = records.len() / 2;
    let mut peer_a = Coordinator::with_engine(cloud.clone(), Engine::native(), 1);
    let mut peer_b = Coordinator::with_engine(cloud.clone(), Engine::native(), 2);
    // measure exchange, not model selection
    peer_a.min_records = usize::MAX;
    peer_b.min_records = usize::MAX;
    peer_a
        .share(&RuntimeDataRepo::from_records(
            JobKind::Sort,
            relabel(&records[..half], "alpha"),
        ))
        .unwrap();
    peer_b
        .share(&RuntimeDataRepo::from_records(
            JobKind::Sort,
            relabel(&records[half..], "beta"),
        ))
        .unwrap();
    (peer_a, peer_b)
}

/// [`seeded_peers`] driven to convergence by one full exchange.
fn converged_peers(
    cloud: &Cloud,
    records: &[RuntimeRecord],
) -> (Coordinator, Coordinator, SyncStats) {
    let (mut peer_a, mut peer_b) = seeded_peers(cloud, records);
    let stats = sync_all(&mut peer_a, &mut peer_b, &[JobKind::Sort]).unwrap();
    (peer_a, peer_b, stats)
}

/// A no-training coordinator with a mesh identity.
fn bench_peer(cloud: &Cloud, seed: u64, mesh_name: &str) -> Coordinator {
    let mut c = Coordinator::with_engine(cloud.clone(), Engine::native(), seed);
    c.min_records = usize::MAX;
    c.set_mesh_name(mesh_name);
    c
}

/// Two no-training peers holding disjoint halves of `per_kind` records
/// for EVERY job kind — the batched scenario's corpus.
fn multi_kind_pair(cloud: &Cloud, per_kind: usize, seed: u64) -> (Coordinator, Coordinator, usize) {
    let mut a = bench_peer(cloud, seed, "bench-a");
    let mut b = bench_peer(cloud, seed + 1, "bench-b");
    let mut total = 0usize;
    for kind in JobKind::all() {
        let records: Vec<RuntimeRecord> = synthetic_records(per_kind)
            .into_iter()
            .map(|mut r| {
                r.job = kind;
                r
            })
            .collect();
        total += records.len();
        let half = records.len() / 2;
        a.share(&RuntimeDataRepo::from_records(
            kind,
            relabel(&records[..half], "alpha"),
        ))
        .unwrap();
        b.share(&RuntimeDataRepo::from_records(
            kind,
            relabel(&records[half..], "beta"),
        ))
        .unwrap();
    }
    (a, b, total)
}

/// One full mesh sweep: every peer runs one [`mesh_round`] against the
/// rest of the roster. Returns (records changed, peer round trips).
fn mesh_sweep(peers: &mut [Coordinator], names: &[String], fanout: usize) -> (u64, u64) {
    let (mut changed, mut trips) = (0u64, 0u64);
    for i in 0..peers.len() {
        let (before, rest) = peers.split_at_mut(i);
        let (local, after) = rest.split_first_mut().unwrap();
        let mut refs: Vec<(String, &mut dyn Client)> = Vec::new();
        for (k, p) in before.iter_mut().enumerate() {
            refs.push((names[k].clone(), p));
        }
        for (k, p) in after.iter_mut().enumerate() {
            refs.push((names[i + 1 + k].clone(), p));
        }
        let report = mesh_round(local, &mut refs, fanout).unwrap();
        changed += report.changed;
        trips += report.peer_round_trips;
    }
    (changed, trips)
}

/// The one-record update both incremental scenarios replay: a fresh
/// configuration contributed by the (existing) org "alpha" on peer A.
fn incremental_record(i: usize) -> RuntimeRecord {
    RuntimeRecord {
        job: JobKind::Sort,
        org: "alpha".into(),
        machine: MACHINES[0].to_string(),
        scaleout: 2,
        job_features: vec![1_000_000.0 + i as f64],
        runtime_s: 123.0,
    }
}

fn main() {
    let n: usize = std::env::var("C3O_SYNC_RECORDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4000);
    let records = synthetic_records(n);

    // ---- replay: WAL-only recovery -------------------------------------
    let root = temp_root("replay");
    {
        let (mut store, mut repo) = JobStore::open(&root, JobKind::Sort).unwrap();
        for chunk in records.chunks(64) {
            let outcome = repo.merge_records(chunk).unwrap();
            let ops: Vec<StoreOp> = outcome
                .applied
                .into_iter()
                .map(|op| StoreOp::Merge {
                    seqno: op.seqno,
                    record: op.record,
                })
                .collect();
            store.append(&ops, repo.generation()).unwrap();
        }
    }
    let t0 = Instant::now();
    let (mut store, repo) = JobStore::open(&root, JobKind::Sort).unwrap();
    let wal_secs = t0.elapsed().as_secs_f64();
    assert_eq!(repo.len(), n, "replay must recover every record");
    let wal_rate = n as f64 / wal_secs;
    println!("replay   WAL      : {n:>6} records in {wal_secs:.3}s  ({wal_rate:>9.0} records/s)");

    // ---- replay: snapshot (+ op-log sidecar) recovery -------------------
    store.compact(&repo).unwrap();
    drop(store);
    let t0 = Instant::now();
    let (_store, repo2) = JobStore::open(&root, JobKind::Sort).unwrap();
    let snap_secs = t0.elapsed().as_secs_f64();
    assert_eq!(repo2.len(), n);
    assert_eq!(repo2.watermarks(), repo.watermarks(), "op logs recover too");
    let snap_rate = n as f64 / snap_secs;
    println!("replay   snapshot : {n:>6} records in {snap_secs:.3}s  ({snap_rate:>9.0} records/s)");
    let _ = std::fs::remove_dir_all(&root);

    // ---- sync: two peers with disjoint org corpora ---------------------
    let cloud = Cloud::aws_like();
    let (mut peer_a, mut peer_b) = seeded_peers(&cloud, &records);
    let t0 = Instant::now();
    let stats = sync_all(&mut peer_a, &mut peer_b, &[JobKind::Sort]).unwrap();
    let sync_secs = t0.elapsed().as_secs_f64();
    let exchanged = stats.records_in + stats.records_out;
    assert_eq!(exchanged as usize, n, "full bidirectional exchange");
    let again = sync_all(&mut peer_a, &mut peer_b, &[JobKind::Sort]).unwrap();
    assert!(again.quiescent(), "second exchange must be a no-op");
    assert_eq!(again.offered, 0, "converged op logs offer nothing");
    let sync_rate = exchanged as f64 / sync_secs;
    println!(
        "sync     exchange : {exchanged:>6} records in {sync_secs:.3}s  ({sync_rate:>9.0} records/s)"
    );

    // ---- incremental: 1 of N changed ------------------------------------
    // v3 (record-level): one new record ships as exactly one op.
    peer_a.contribute(incremental_record(0)).unwrap();
    let t0 = Instant::now();
    let inc_v3 = sync_job(&mut peer_a, &mut peer_b, JobKind::Sort).unwrap();
    let inc_v3_secs = t0.elapsed().as_secs_f64();
    assert_eq!(inc_v3.records_in + inc_v3.records_out, 1);
    assert_eq!(inc_v3.offered, 1, "v3 ships exactly the changed record");

    // v2-equivalent (org-granular) on an identically-converged pair: the
    // same one-record change re-ships the whole changed org.
    let (mut v2_a, mut v2_b, _) = converged_peers(&cloud, &records);
    v2_a.contribute(incremental_record(0)).unwrap();
    let t0 = Instant::now();
    let inc_v2 = sync_job_v2(&mut v2_a, &mut v2_b, JobKind::Sort).unwrap();
    let inc_v2_secs = t0.elapsed().as_secs_f64();
    assert_eq!(inc_v2.records_in + inc_v2.records_out, 1, "same data lands");
    assert!(inc_v2.offered > 1, "v2 re-ships the whole changed org");

    let ratio = inc_v2.offered as f64 / inc_v3.offered as f64;
    println!(
        "incremental (1 of {n} changed): v3 ships {} record(s) in {inc_v3_secs:.4}s, \
         v2-equivalent ships {} in {inc_v2_secs:.4}s  ({ratio:.0}x fewer records at v3)",
        inc_v3.offered, inc_v2.offered
    );
    assert!(
        ratio >= 10.0,
        "record-level sync must ship >= 10x fewer records than the org-granular path \
         (got {ratio:.1}x: v3 {} vs v2 {})",
        inc_v3.offered,
        inc_v2.offered
    );

    // ---- batched (v4) vs per-job (v3): round trips ----------------------
    let per_kind = (n / 10).max(50);
    let kinds = JobKind::all();
    let (mut v3_a, mut v3_b, multi_total) = multi_kind_pair(&cloud, per_kind, 40);
    let v3_opts = SyncOptions::default(); // every kind, one conversation per kind
    let v3_full = sync(&mut v3_a, &mut v3_b, &v3_opts).unwrap().stats;
    assert_eq!((v3_full.records_in + v3_full.records_out) as usize, multi_total);
    let v3_idle = sync(&mut v3_a, &mut v3_b, &v3_opts).unwrap().stats;
    assert!(v3_idle.quiescent());

    let (mut v4_a, mut v4_b, _) = multi_kind_pair(&cloud, per_kind, 50);
    let v4_opts = SyncOptions {
        protocol: SyncProtocol::BatchedV4,
        ..SyncOptions::default()
    };
    let t0 = Instant::now();
    let v4_full = sync(&mut v4_a, &mut v4_b, &v4_opts).unwrap().stats;
    let v4_secs = t0.elapsed().as_secs_f64();
    assert_eq!(
        (v4_full.records_in + v4_full.records_out) as usize,
        multi_total,
        "the batched exchange is still a full exchange"
    );
    let v4_idle = sync(&mut v4_a, &mut v4_b, &v4_opts).unwrap().stats;
    assert!(v4_idle.quiescent());
    assert!(
        v4_full.round_trips < v3_full.round_trips,
        "cross-job batching must cost fewer round trips than per-job sync \
         (v4 {} vs v3 {})",
        v4_full.round_trips,
        v3_full.round_trips
    );
    assert!(
        v4_idle.round_trips < v3_idle.round_trips,
        "idle maintenance rounds batch too (v4 {} vs v3 {})",
        v4_idle.round_trips,
        v3_idle.round_trips
    );
    println!(
        "batched  ({} kinds): v4 {} round trips vs v3 {} full ({} vs {} idle), \
         {multi_total} records in {v4_secs:.3}s",
        kinds.len(),
        v4_full.round_trips,
        v3_full.round_trips,
        v4_idle.round_trips,
        v3_idle.round_trips
    );

    // ---- mesh: roster-scheduled gossip with acked-floor truncation ------
    let mesh_n = 3usize;
    let names: Vec<String> = (0..mesh_n).map(|i| format!("peer-{i}")).collect();
    let mut mesh_peers: Vec<Coordinator> = (0..mesh_n)
        .map(|i| bench_peer(&cloud, 60 + i as u64, &names[i]))
        .collect();
    for (i, p) in mesh_peers.iter_mut().enumerate() {
        let slice: Vec<RuntimeRecord> = records
            .iter()
            .enumerate()
            .filter(|(r, _)| r % mesh_n == i)
            .map(|(_, rec)| rec.with_org(&format!("org-{i}")))
            .collect();
        p.share(&RuntimeDataRepo::from_records(JobKind::Sort, slice))
            .unwrap();
    }
    let intro: Vec<MeshPeer> = names.iter().map(|name| mesh_peer(name)).collect();
    for (i, p) in mesh_peers.iter_mut().enumerate() {
        p.mesh_hello(MeshHello {
            from: intro[(i + 1) % mesh_n].clone(),
            known: intro.clone(),
            acked: Vec::new(),
        })
        .unwrap();
    }
    let t0 = Instant::now();
    let (mut sweeps, mut trips, mut moved) = (0u64, 0u64, 0u64);
    let mut converged = false;
    for _ in 0..64 {
        let (changed, t) = mesh_sweep(&mut mesh_peers, &names, 1);
        sweeps += 1;
        trips += t;
        moved += changed;
        let reference = mesh_peers[0].repo(JobKind::Sort).map(|r| r.content_digest());
        if changed == 0
            && mesh_peers[1..]
                .iter()
                .all(|p| p.repo(JobKind::Sort).map(|r| r.content_digest()) == reference)
        {
            converged = true;
            break;
        }
    }
    assert!(converged, "mesh did not converge within 64 sweeps");
    // ack propagation + the truncating self-ticks
    for _ in 0..2 * mesh_n + 2 {
        let (_, t) = mesh_sweep(&mut mesh_peers, &names, 1);
        trips += t;
    }
    let mesh_secs = t0.elapsed().as_secs_f64();
    let truncated: u64 = mesh_peers.iter().map(|p| p.metrics().ops_truncated).sum();
    let retained: usize = mesh_peers
        .iter()
        .map(|p| p.repo(JobKind::Sort).unwrap().retained_log_entries())
        .sum();
    assert!(truncated > 0, "acked floors truncated the op logs");
    assert_eq!(retained, 0, "only the unacked suffix is retained");
    let mesh_rate = moved as f64 / mesh_secs;
    println!(
        "mesh     ({mesh_n} peers, fanout 1): {moved} records moved in {sweeps} sweeps, \
         {trips} peer round trips, {mesh_secs:.3}s  ({mesh_rate:>9.0} records/s), \
         {truncated} ops truncated"
    );

    let json = Json::obj(vec![
        ("bench", Json::Str("sync_throughput".to_string())),
        ("records", Json::Num(n as f64)),
        (
            "replay",
            Json::obj(vec![
                ("wal_records_per_s", Json::Num(wal_rate)),
                ("snapshot_records_per_s", Json::Num(snap_rate)),
            ]),
        ),
        (
            "sync",
            Json::obj(vec![
                ("records_exchanged", Json::Num(exchanged as f64)),
                ("records_per_s", Json::Num(sync_rate)),
                ("pulls", Json::Num(stats.pulls as f64)),
                ("conflicts", Json::Num(stats.conflicts as f64)),
            ]),
        ),
        (
            "incremental",
            Json::obj(vec![
                ("changed_records", Json::Num(1.0)),
                ("v3_records_shipped", Json::Num(inc_v3.offered as f64)),
                ("v2_records_shipped", Json::Num(inc_v2.offered as f64)),
                ("ship_ratio_v2_over_v3", Json::Num(ratio)),
                ("v3_exchange_s", Json::Num(inc_v3_secs)),
                ("v2_exchange_s", Json::Num(inc_v2_secs)),
            ]),
        ),
        (
            "batched",
            Json::obj(vec![
                ("job_kinds", Json::Num(kinds.len() as f64)),
                ("records", Json::Num(multi_total as f64)),
                ("v3_round_trips", Json::Num(v3_full.round_trips as f64)),
                ("v4_round_trips", Json::Num(v4_full.round_trips as f64)),
                ("v3_idle_round_trips", Json::Num(v3_idle.round_trips as f64)),
                ("v4_idle_round_trips", Json::Num(v4_idle.round_trips as f64)),
                ("v4_records_per_s", Json::Num(multi_total as f64 / v4_secs)),
            ]),
        ),
        (
            "mesh",
            Json::obj(vec![
                ("peers", Json::Num(mesh_n as f64)),
                ("fanout", Json::Num(1.0)),
                ("sweeps_to_converge", Json::Num(sweeps as f64)),
                ("peer_round_trips", Json::Num(trips as f64)),
                ("records_moved", Json::Num(moved as f64)),
                ("records_per_s", Json::Num(mesh_rate)),
                ("ops_truncated", Json::Num(truncated as f64)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_sync_throughput.json", json.render() + "\n").unwrap();
    println!("wrote BENCH_sync_throughput.json");
}
