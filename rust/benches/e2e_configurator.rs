//! Bench: end-to-end configuration quality — C3O vs the related-work
//! baselines (paper §II + the system claim of §III/§VI).
//!
//! For a battery of jobs with runtime targets, each approach decides a
//! configuration; we then measure (with the noise-free oracle):
//!
//! * the **true cost** of running the job on the chosen configuration,
//! * whether the **target** is actually met,
//! * the **cost of deciding** (profiling runs × cluster time, incl. the
//!   ~7-minute EMR provisioning delay per probe cluster),
//! * **regret** vs the true optimal configuration on the candidate grid.
//!
//! Claims asserted: C3O meets ≥ as many targets as the naive strategies,
//! decides with *zero* profiling cost, and its total (decide + run) cost
//! beats every profiling-based baseline.

use c3o::baselines::{CherryPick, ConfigSearch, Ernest, NaiveCheapest, NaiveMax, NaiveRandom};
use c3o::cloud::Cloud;
use c3o::configurator::JobRequest;
use c3o::coordinator::{Coordinator, Organization};
use c3o::models::oracle::SimOracle;
use c3o::models::ConfigQuery;
use c3o::runtime::Runtime;
use c3o::util::bench::Bench;
use c3o::workloads::{ExperimentGrid, JobKind};

struct Row {
    approach: &'static str,
    run_cost: f64,
    decide_cost: f64,
    targets_met: usize,
    regret: f64,
}

fn true_run(cloud: &Cloud, req: &JobRequest, machine: &str, n: u32) -> (f64, f64) {
    let mut oracle = SimOracle::deterministic(req.kind(), 1234);
    let q = ConfigQuery {
        machine: machine.to_string(),
        scaleout: n,
        job_features: req.spec.job_features(),
    };
    let t = oracle.run_once(cloud, &q).unwrap();
    (t, cloud.cost_usd(machine, n, t + 7.0 * 60.0))
}

/// True optimal (cheapest meeting target) over the xlarge grid.
fn optimal_cost(cloud: &Cloud, req: &JobRequest) -> f64 {
    let mut best = f64::INFINITY;
    let mut fallback = f64::INFINITY;
    for m in ["c5.xlarge", "m5.xlarge", "r5.xlarge"] {
        for n in 2..=12 {
            let (t, cost) = true_run(cloud, req, m, n);
            fallback = fallback.min(cost);
            if req.target_s.map_or(true, |tt| t <= tt) {
                best = best.min(cost);
            }
        }
    }
    if best.is_finite() {
        best
    } else {
        fallback
    }
}

fn main() {
    let dir = Runtime::default_dir();
    if !Runtime::artifacts_available(&dir) {
        eprintln!("SKIP e2e_configurator: artifacts not built (run `make artifacts`)");
        return;
    }
    let cloud = Cloud::aws_like();

    let battery: Vec<JobRequest> = vec![
        JobRequest::sort(13.0).with_target_seconds(350.0),
        JobRequest::sort(18.0).with_target_seconds(600.0),
        JobRequest::grep(12.0, 0.1).with_target_seconds(250.0),
        JobRequest::grep(19.0, 0.3).with_target_seconds(450.0),
        JobRequest::sgd(24.0, 70).with_target_seconds(900.0),
        JobRequest::sgd(28.0, 100).with_target_seconds(1500.0),
        JobRequest::kmeans(14.0, 6, 0.001).with_target_seconds(900.0),
        JobRequest::kmeans(19.0, 4, 0.001).with_target_seconds(600.0),
        JobRequest::pagerank(220.0, 0.001).with_target_seconds(300.0),
        JobRequest::pagerank(400.0, 0.0001).with_target_seconds(800.0),
    ];

    // --- C3O: coordinator over the shared corpus --------------------------
    println!("seeding C3O with the 930-run shared corpus...");
    let corpus = ExperimentGrid::paper_table1().execute(&cloud, 42);
    let mut coord = Coordinator::new(cloud.clone(), &dir, 5).unwrap();
    for kind in JobKind::all() {
        coord.share(&corpus.repo_for(kind)).unwrap();
    }
    let org = Organization::new("bench-org");

    let mut rows: Vec<Row> = Vec::new();
    {
        let mut run_cost = 0.0;
        let mut met = 0;
        let mut regret = 0.0;
        for req in &battery {
            let o = coord.submit(&org, req).unwrap();
            let (t, cost) = true_run(&cloud, req, &o.machine, o.scaleout);
            run_cost += cost;
            if req.target_s.map_or(true, |tt| t <= tt) {
                met += 1;
            }
            regret += cost / optimal_cost(&cloud, req);
        }
        rows.push(Row {
            approach: "c3o",
            run_cost,
            decide_cost: 0.0,
            targets_met: met,
            regret: regret / battery.len() as f64,
        });
    }

    // --- baselines ----------------------------------------------------------
    let mut run_baseline = |name: &'static str, search: &mut dyn ConfigSearch| {
        let mut run_cost = 0.0;
        let mut decide_cost = 0.0;
        let mut met = 0;
        let mut regret = 0.0;
        for req in &battery {
            let mut oracle = SimOracle::deterministic(req.kind(), 777);
            let out = search.search(&cloud, &mut oracle, req).unwrap();
            decide_cost += out.profiling_cost_usd;
            let (t, cost) = true_run(&cloud, req, &out.machine, out.scaleout);
            run_cost += cost;
            if req.target_s.map_or(true, |tt| t <= tt) {
                met += 1;
            }
            regret += cost / optimal_cost(&cloud, req);
        }
        rows.push(Row {
            approach: name,
            run_cost,
            decide_cost,
            targets_met: met,
            regret: regret / battery.len() as f64,
        });
    };
    run_baseline("cherrypick", &mut CherryPick::default());
    run_baseline("ernest", &mut Ernest::default());
    run_baseline("naive-max", &mut NaiveMax::default());
    run_baseline("naive-cheapest", &mut NaiveCheapest);
    run_baseline("naive-random", &mut NaiveRandom::new(3));

    // --- report ---------------------------------------------------------------
    println!("\n== configuration quality over a 10-job battery (targets attached) ==\n");
    println!(
        "{:<15} {:>10} {:>12} {:>12} {:>12} {:>8}",
        "approach", "run_$", "decide_$", "total_$", "targets", "regret"
    );
    for r in &rows {
        println!(
            "{:<15} {:>10.2} {:>12.2} {:>12.2} {:>9}/10 {:>8.2}",
            r.approach,
            r.run_cost,
            r.decide_cost,
            r.run_cost + r.decide_cost,
            r.targets_met,
            r.regret
        );
    }

    let c3o = &rows[0];
    let total = |r: &Row| r.run_cost + r.decide_cost;
    assert_eq!(c3o.decide_cost, 0.0, "C3O must not profile");
    for r in &rows[1..] {
        if r.approach == "cherrypick" || r.approach == "ernest" {
            assert!(
                total(c3o) < total(r),
                "C3O total ${:.2} must beat {} ${:.2} (profiling overhead)",
                total(c3o),
                r.approach,
                total(r)
            );
        }
    }
    let naive_max_met = rows
        .iter()
        .find(|r| r.approach == "naive-max")
        .unwrap()
        .targets_met;
    assert!(
        c3o.targets_met + 1 >= naive_max_met,
        "C3O should meet (nearly) as many targets as overprovisioning"
    );
    assert!(c3o.regret < 2.0, "C3O regret {:.2} too high", c3o.regret);
    println!("\nall §III/§VI system claims PASSED");

    // --- timing: decision latency -------------------------------------------
    let mut b = Bench::new("e2e_configurator");
    let req = JobRequest::sort(15.0).with_target_seconds(400.0);
    b.run("c3o_submit_warm", || {
        coord.submit(&org, &req).unwrap().scaleout
    });
    b.finish();
}
