//! Structured tracing & latency observability for the serving stack.
//!
//! The paper's collaborative pitch only holds if shared-data serving
//! stays cheap as contributions accumulate — so the server applies the
//! C3O lens to itself and captures runtime data about its *own*
//! executions. Three layers:
//!
//! * **Per-request span traces** — every request handled by the
//!   concurrent service carries a [`Trace`]: a fixed-capacity list of
//!   monotonic [`Stage`] spans (queue wait, coalesce-group assembly,
//!   shard-lock wait, featurize/cross-validate/winner-fit, pool wait,
//!   predict, WAL append, fsync, reply) recorded through RAII
//!   [`SpanGuard`]s.
//!   Finished traces are `force_push`ed into per-worker lock-free
//!   [`ring::Ring`]s — allocation-free on the hot path, bounded, and
//!   drained by the service when a report or export is requested.
//!   Stages measured *inside* a shard (the retrain split, WAL I/O)
//!   surface as durations via [`StageScratch`]; the service lays them
//!   out back-to-front ending at the drain instant, so their widths
//!   are exact while their offsets are reconstructed.
//! * **Log-bucketed latency histograms** — drained traces fold into a
//!   [`hist::LatencyMatrix`] (request kind × stage), fixed power-of-2
//!   buckets with exact-given-bucketing p50/p95/p99 ([`hist`]). All
//!   array math, no maps: the matrix is registered in the lint's
//!   deterministic zone.
//! * **Exporters** — [`Collector::chrome_trace_json`] renders the
//!   retained trace window as Chrome trace-event JSON (loadable in
//!   Perfetto / `chrome://tracing`); [`Report::to_json`] is the
//!   `latency` block of `c3o serve --json`; [`SlowCapture`] retains
//!   the K worst full span breakdowns per request kind.
//!
//! Tracing is **behaviorally inert**: a disabled collector hands out
//! no-op traces ([`Trace::off`]) that never read the clock, and an
//! enabled one only ever *observes* timings — the client suite asserts
//! bitwise-identical decisions either way, and `serve_throughput`
//! records the overhead.

pub mod hist;
pub mod ring;

pub use hist::{Histogram, LatencyMatrix};
pub use ring::Ring;

use crate::util::json::Json;
use crate::util::sync::LockExt;
use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

/// One pipeline stage of a request's life. `Total` is the synthetic
/// end-to-end span the collector seals onto every trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Channel time between enqueue and a worker picking the item up.
    QueueWait,
    /// Draining same-kind neighbors into a coalesced batch.
    CoalesceAssembly,
    /// Blocking on the shard mutex (write path only).
    ShardLockWait,
    /// Feature-matrix refresh ahead of a retrain.
    Featurize,
    /// Cross-validation over the candidate model kinds.
    CrossValidate,
    /// Fitting the CV winner on the full repository.
    WinnerFit,
    /// Waiting on compute-pool helper threads during a parallel fan
    /// (ordered collection time in [`crate::compute::ComputePool`]).
    PoolWait,
    /// Model inference (batch candidate scoring).
    Predict,
    /// WAL line rendering + write + flush.
    WalAppend,
    /// `fsync` of the WAL segment.
    Fsync,
    /// Delivering replies to the waiting clients.
    Reply,
    /// The whole request, enqueue to reply.
    Total,
}

impl Stage {
    pub const COUNT: usize = 12;
    pub const ALL: [Stage; Stage::COUNT] = [
        Stage::QueueWait,
        Stage::CoalesceAssembly,
        Stage::ShardLockWait,
        Stage::Featurize,
        Stage::CrossValidate,
        Stage::WinnerFit,
        Stage::PoolWait,
        Stage::Predict,
        Stage::WalAppend,
        Stage::Fsync,
        Stage::Reply,
        Stage::Total,
    ];

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            Stage::QueueWait => "queue_wait",
            Stage::CoalesceAssembly => "coalesce_assembly",
            Stage::ShardLockWait => "shard_lock_wait",
            Stage::Featurize => "featurize",
            Stage::CrossValidate => "cross_validate",
            Stage::WinnerFit => "winner_fit",
            Stage::PoolWait => "pool_wait",
            Stage::Predict => "predict",
            Stage::WalAppend => "wal_append",
            Stage::Fsync => "fsync",
            Stage::Reply => "reply",
            Stage::Total => "total",
        }
    }
}

/// The request classes latency is keyed by.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReqKind {
    Recommend,
    Submit,
    Contribute,
    Share,
    /// Watermarks / SyncPull / SyncPush (either protocol version).
    Sync,
    /// Metrics, snapshot info, and anything else cheap.
    Other,
}

impl ReqKind {
    pub const COUNT: usize = 6;
    pub const ALL: [ReqKind; ReqKind::COUNT] = [
        ReqKind::Recommend,
        ReqKind::Submit,
        ReqKind::Contribute,
        ReqKind::Share,
        ReqKind::Sync,
        ReqKind::Other,
    ];

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(self) -> &'static str {
        match self {
            ReqKind::Recommend => "recommend",
            ReqKind::Submit => "submit",
            ReqKind::Contribute => "contribute",
            ReqKind::Share => "share",
            ReqKind::Sync => "sync",
            ReqKind::Other => "other",
        }
    }
}

/// One recorded stage span: offsets are nanoseconds since the
/// collector's epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub stage: Stage,
    pub start_ns: u64,
    pub dur_ns: u64,
}

const ZERO_SPAN: Span = Span {
    stage: Stage::Total,
    start_ns: 0,
    dur_ns: 0,
};

/// Spans one trace can hold; the write path records ~10.
pub const TRACE_SPAN_CAP: usize = 16;

/// The span record one request carries through the pipeline.
/// Fixed-size, `Copy`-free but allocation-free; an inactive trace
/// (`Trace::off`) never reads the clock.
#[derive(Debug, Clone)]
pub struct Trace {
    kind: ReqKind,
    worker: u32,
    /// Requests answered by this trace (coalesced group size).
    group: u32,
    /// Trace start, nanoseconds since the collector epoch.
    start_ns: u64,
    /// The collector epoch; `None` = tracing disabled (no-op trace).
    epoch: Option<Instant>,
    spans: [Span; TRACE_SPAN_CAP],
    len: u8,
    /// Spans discarded because the fixed array filled up.
    dropped_spans: u8,
}

fn ns_between(earlier: Instant, later: Instant) -> u64 {
    later.duration_since(earlier).as_nanos() as u64
}

impl Trace {
    /// A disabled trace: every recording call is a no-op and no clock
    /// is ever read.
    pub fn off() -> Trace {
        Trace {
            kind: ReqKind::Other,
            worker: 0,
            group: 1,
            start_ns: 0,
            epoch: None,
            spans: [ZERO_SPAN; TRACE_SPAN_CAP],
            len: 0,
            dropped_spans: 0,
        }
    }

    /// An active trace starting now.
    pub fn start(kind: ReqKind, worker: u32, epoch: Instant) -> Trace {
        let mut t = Trace::off();
        t.kind = kind;
        t.worker = worker;
        t.start_ns = ns_between(epoch, Instant::now());
        t.epoch = Some(epoch);
        t
    }

    pub fn is_on(&self) -> bool {
        self.epoch.is_some()
    }

    pub fn kind(&self) -> ReqKind {
        self.kind
    }

    pub fn worker(&self) -> u32 {
        self.worker
    }

    pub fn group(&self) -> u32 {
        self.group
    }

    pub fn set_group(&mut self, n: u32) {
        self.group = n.max(1);
    }

    pub fn spans(&self) -> &[Span] {
        &self.spans[..self.len as usize]
    }

    pub fn dropped_spans(&self) -> u8 {
        self.dropped_spans
    }

    pub fn start_ns(&self) -> u64 {
        self.start_ns
    }

    /// Nanoseconds since the collector epoch (0 when disabled).
    pub fn now_rel_ns(&self) -> u64 {
        self.epoch.map_or(0, |e| ns_between(e, Instant::now()))
    }

    /// Open a stage span; it records itself when the guard drops.
    pub fn span(&mut self, stage: Stage) -> SpanGuard<'_> {
        let started = self.epoch.map(|_| Instant::now());
        SpanGuard {
            trace: self,
            stage,
            started,
        }
    }

    /// Record a span that began at `at` (e.g. the enqueue instant) and
    /// ends now.
    pub fn span_from(&mut self, stage: Stage, at: Instant) {
        if let Some(epoch) = self.epoch {
            let end = Instant::now();
            self.push_span(stage, ns_between(epoch, at), ns_between(at, end));
        }
    }

    /// Record a duration-only span laid out to *end* at `end_rel_ns`
    /// (stages measured inside the shard expose durations, not start
    /// instants — widths are exact, offsets reconstructed).
    pub fn push_dur(&mut self, stage: Stage, dur_ns: u64, end_rel_ns: u64) {
        if self.epoch.is_some() && dur_ns > 0 {
            self.push_span(stage, end_rel_ns.saturating_sub(dur_ns), dur_ns);
        }
    }

    fn push_span(&mut self, stage: Stage, start_ns: u64, dur_ns: u64) {
        if (self.len as usize) < TRACE_SPAN_CAP {
            self.spans[self.len as usize] = Span {
                stage,
                start_ns,
                dur_ns,
            };
            self.len += 1;
        } else {
            self.dropped_spans = self.dropped_spans.saturating_add(1);
        }
    }

    /// End-to-end duration (the sealed `Total` span, or 0 pre-seal).
    pub fn total_ns(&self) -> u64 {
        self.spans()
            .iter()
            .find(|s| s.stage == Stage::Total)
            .map_or(0, |s| s.dur_ns)
    }

    /// Seal the trace with its synthetic `Total` span, enqueue → now.
    fn seal(&mut self) {
        if self.epoch.is_some() {
            let total = self.now_rel_ns().saturating_sub(self.start_ns);
            self.push_span(Stage::Total, self.start_ns, total);
        }
    }
}

/// RAII span: opened by [`Trace::span`], records on drop. On an
/// inactive trace the guard holds no instant and drops for free.
pub struct SpanGuard<'t> {
    trace: &'t mut Trace,
    stage: Stage,
    started: Option<Instant>,
}

impl SpanGuard<'_> {
    /// Explicitly end the span (alias for dropping the guard).
    pub fn end(self) {}
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        if let (Some(t0), Some(epoch)) = (self.started.take(), self.trace.epoch) {
            let end = Instant::now();
            self.trace
                .push_span(self.stage, ns_between(epoch, t0), ns_between(t0, end));
        }
    }
}

/// Per-stage nanosecond accumulator for code that cannot carry a
/// `Trace` (shard internals, the store). Writers `add` durations; the
/// service `take`s the array while still holding the shard lock and
/// converts it into trace spans. A fixed array: the sequential
/// coordinator never drains it, and that is harmless.
#[derive(Debug, Clone)]
pub struct StageScratch {
    nanos: [u64; Stage::COUNT],
}

impl Default for StageScratch {
    fn default() -> Self {
        StageScratch {
            nanos: [0; Stage::COUNT],
        }
    }
}

impl StageScratch {
    pub fn add(&mut self, stage: Stage, ns: u64) {
        self.nanos[stage.index()] = self.nanos[stage.index()].saturating_add(ns);
    }

    /// Take and reset the accumulated durations, indexed by
    /// [`Stage::index`].
    pub fn take(&mut self) -> [u64; Stage::COUNT] {
        let out = self.nanos;
        self.nanos = [0; Stage::COUNT];
        out
    }
}

/// Worst-K full span breakdowns per request kind, ranked by total
/// duration.
#[derive(Debug, Clone, Default)]
pub struct SlowCapture {
    worst: [Vec<Trace>; ReqKind::COUNT],
}

/// Slow traces retained per request kind.
pub const SLOW_CAPTURE_K: usize = 4;

impl SlowCapture {
    fn offer(&mut self, trace: &Trace) {
        let lane = &mut self.worst[trace.kind.index()];
        let total = trace.total_ns();
        if lane.len() == SLOW_CAPTURE_K
            && total <= lane.last().map_or(0, |t| t.total_ns())
        {
            return;
        }
        let at = lane
            .iter()
            .position(|t| t.total_ns() < total)
            .unwrap_or(lane.len());
        lane.insert(at, trace.clone());
        lane.truncate(SLOW_CAPTURE_K);
    }

    /// Retained traces for one kind, slowest first.
    pub fn worst(&self, kind: ReqKind) -> &[Trace] {
        &self.worst[kind.index()]
    }

    fn to_json(&self) -> Json {
        let rows: Vec<Json> = ReqKind::ALL
            .iter()
            .copied()
            .flat_map(|k| self.worst[k.index()].iter())
            .map(|t| {
                let spans: Vec<Json> = t
                    .spans()
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("stage", Json::Str(s.stage.name().to_string())),
                            ("start_us", Json::Num(s.start_ns as f64 / 1000.0)),
                            ("dur_us", Json::Num(s.dur_ns as f64 / 1000.0)),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    ("kind", Json::Str(t.kind.name().to_string())),
                    ("worker", Json::Num(t.worker as f64)),
                    ("group", Json::Num(t.group as f64)),
                    ("total_us", Json::Num(t.total_ns() as f64 / 1000.0)),
                    ("spans", Json::Arr(spans)),
                ])
            })
            .collect();
        Json::Arr(rows)
    }
}

/// Traces the collector retains for the Chrome export (drop-oldest).
const EXPORT_WINDOW_CAP: usize = 4096;

/// Per-worker trace ring capacity.
const LANE_CAP: usize = 1024;

/// What the collector has aggregated so far, behind its internal
/// mutex (folded only on drains — never on the request hot path).
#[derive(Debug, Clone, Default)]
struct Aggregate {
    lat: LatencyMatrix,
    slow: SlowCapture,
    window: VecDeque<Trace>,
    drained: u64,
}

impl Aggregate {
    fn fold(&mut self, trace: Trace) {
        for s in trace.spans() {
            self.lat.record(trace.kind, s.stage, s.dur_ns);
        }
        self.slow.offer(&trace);
        self.drained += 1;
        if self.window.len() == EXPORT_WINDOW_CAP {
            self.window.pop_front();
        }
        self.window.push_back(trace);
    }
}

/// The service-wide trace collector: hands out traces, owns the
/// per-worker rings, and aggregates drained traces into histograms,
/// the slow capture, and the Chrome-export window.
#[derive(Debug)]
pub struct Collector {
    enabled: bool,
    epoch: Instant,
    lanes: Vec<Ring<Trace>>,
    agg: Mutex<Aggregate>,
}

impl Collector {
    pub fn new(workers: usize, enabled: bool) -> Collector {
        Collector {
            enabled,
            epoch: Instant::now(),
            lanes: (0..workers.max(1)).map(|_| Ring::new(LANE_CAP)).collect(),
            agg: Mutex::new(Aggregate::default()),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// A trace for one request on `worker` — active iff the collector
    /// is enabled.
    pub fn trace(&self, kind: ReqKind, worker: usize) -> Trace {
        if self.enabled {
            Trace::start(kind, worker as u32, self.epoch)
        } else {
            Trace::off()
        }
    }

    /// Hot path: seal a finished trace and push it into its worker's
    /// ring. Lock-free, allocation-free; inactive traces are dropped.
    pub fn ingest(&self, mut trace: Trace) {
        if !trace.is_on() {
            return;
        }
        trace.seal();
        let lane = trace.worker as usize % self.lanes.len();
        self.lanes[lane].force_push(trace);
    }

    /// Drain every worker ring into the aggregate.
    fn drain(&self) {
        let mut agg = self.agg.lock_unpoisoned();
        for lane in &self.lanes {
            while let Some(t) = lane.pop() {
                agg.fold(t);
            }
        }
    }

    /// Traces overwritten in the rings before any drain saw them.
    pub fn lost(&self) -> u64 {
        self.lanes.iter().map(|l| l.lost()).sum()
    }

    /// Drain and snapshot the aggregate.
    pub fn report(&self) -> Report {
        self.drain();
        let agg = self.agg.lock_unpoisoned();
        Report {
            lat: agg.lat.clone(),
            slow: agg.slow.clone(),
            drained: agg.drained,
            lost: self.lost(),
        }
    }

    /// Drain and render the retained trace window as Chrome trace-event
    /// JSON (the `--trace-out` payload; loadable in Perfetto and
    /// `chrome://tracing`).
    pub fn chrome_trace_json(&self) -> Json {
        self.drain();
        let agg = self.agg.lock_unpoisoned();
        let mut events: Vec<Json> = Vec::new();
        events.push(Json::obj(vec![
            ("name", Json::Str("process_name".into())),
            ("ph", Json::Str("M".into())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(0.0)),
            (
                "args",
                Json::obj(vec![("name", Json::Str("c3o serve".into()))]),
            ),
        ]));
        let workers = self.lanes.len();
        for w in 0..workers {
            events.push(Json::obj(vec![
                ("name", Json::Str("thread_name".into())),
                ("ph", Json::Str("M".into())),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num((w + 1) as f64)),
                (
                    "args",
                    Json::obj(vec![("name", Json::Str(format!("worker-{w}")))]),
                ),
            ]));
        }
        for t in &agg.window {
            for s in t.spans() {
                events.push(Json::obj(vec![
                    ("name", Json::Str(s.stage.name().to_string())),
                    ("cat", Json::Str(t.kind.name().to_string())),
                    ("ph", Json::Str("X".into())),
                    ("pid", Json::Num(1.0)),
                    ("tid", Json::Num((t.worker + 1) as f64)),
                    ("ts", Json::Num(s.start_ns as f64 / 1000.0)),
                    ("dur", Json::Num(s.dur_ns as f64 / 1000.0)),
                    (
                        "args",
                        Json::obj(vec![("group", Json::Num(t.group as f64))]),
                    ),
                ]));
            }
        }
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::Str("ms".into())),
        ])
    }
}

/// A drained observability snapshot: the latency matrix, the worst-K
/// slow traces, and the drain/loss accounting.
#[derive(Debug, Clone)]
pub struct Report {
    pub lat: LatencyMatrix,
    pub slow: SlowCapture,
    /// Traces folded into the aggregate so far.
    pub drained: u64,
    /// Traces overwritten in the rings before a drain saw them.
    pub lost: u64,
}

impl Report {
    pub fn is_empty(&self) -> bool {
        self.drained == 0
    }

    /// The `latency` block of `c3o serve --json`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("traces", Json::Num(self.drained as f64)),
            ("traces_lost", Json::Num(self.lost as f64)),
            ("kinds", self.lat.to_json()),
            ("slowest", self.slow.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_trace_records_nothing() {
        let mut t = Trace::off();
        assert!(!t.is_on());
        t.span(Stage::Predict).end();
        t.span_from(Stage::QueueWait, Instant::now());
        t.push_dur(Stage::Fsync, 123, 456);
        assert!(t.spans().is_empty());
        assert_eq!(t.total_ns(), 0);
    }

    #[test]
    fn span_guards_record_on_drop() {
        let epoch = Instant::now();
        let mut t = Trace::start(ReqKind::Submit, 3, epoch);
        {
            let _g = t.span(Stage::Predict);
            std::hint::black_box(0u64);
        }
        t.push_dur(Stage::Fsync, 500, t.now_rel_ns());
        assert_eq!(t.spans().len(), 2);
        assert_eq!(t.spans()[0].stage, Stage::Predict);
        assert_eq!(t.spans()[1].stage, Stage::Fsync);
        assert_eq!(t.spans()[1].dur_ns, 500);
        assert_eq!(t.worker(), 3);
    }

    #[test]
    fn span_overflow_is_counted_not_grown() {
        let mut t = Trace::start(ReqKind::Other, 0, Instant::now());
        for _ in 0..TRACE_SPAN_CAP + 5 {
            t.push_dur(Stage::Reply, 1, 1);
        }
        assert_eq!(t.spans().len(), TRACE_SPAN_CAP);
        assert_eq!(t.dropped_spans(), 5);
    }

    #[test]
    fn collector_round_trip() {
        let c = Collector::new(2, true);
        for i in 0..10u32 {
            let mut t = c.trace(ReqKind::Recommend, (i % 2) as usize);
            t.push_dur(Stage::Predict, 1000 + u64::from(i), t.now_rel_ns());
            c.ingest(t);
        }
        let report = c.report();
        assert_eq!(report.drained, 10);
        assert_eq!(report.lost, 0);
        assert_eq!(
            report.lat.cell(ReqKind::Recommend, Stage::Predict).count(),
            10
        );
        assert_eq!(report.lat.cell(ReqKind::Recommend, Stage::Total).count(), 10);
        assert_eq!(report.slow.worst(ReqKind::Recommend).len(), SLOW_CAPTURE_K);
        // the chrome export holds every span of the drained window
        let doc = c.chrome_trace_json();
        let rendered = doc.render();
        assert!(rendered.contains("\"traceEvents\""));
        assert!(rendered.contains("\"predict\""));
        assert!(rendered.contains("\"ph\":\"X\""));
    }

    #[test]
    fn disabled_collector_is_inert() {
        let c = Collector::new(2, false);
        let mut t = c.trace(ReqKind::Submit, 0);
        assert!(!t.is_on());
        t.span(Stage::Predict).end();
        c.ingest(t);
        let report = c.report();
        assert!(report.is_empty());
        assert!(report.lat.is_empty());
    }

    #[test]
    fn slow_capture_keeps_the_worst_k_sorted() {
        let mut cap = SlowCapture::default();
        let epoch = Instant::now();
        for total in [5u64, 90, 10, 70, 40, 100, 1] {
            let mut t = Trace::start(ReqKind::Submit, 0, epoch);
            // hand-seal with a known total
            t.push_span(Stage::Total, 0, total);
            cap.offer(&t);
        }
        let worst: Vec<u64> = cap
            .worst(ReqKind::Submit)
            .iter()
            .map(|t| t.total_ns())
            .collect();
        assert_eq!(worst, vec![100, 90, 70, 40]);
    }

    #[test]
    fn scratch_accumulates_and_resets() {
        let mut s = StageScratch::default();
        s.add(Stage::Featurize, 10);
        s.add(Stage::Featurize, 5);
        s.add(Stage::Fsync, 7);
        let taken = s.take();
        assert_eq!(taken[Stage::Featurize.index()], 15);
        assert_eq!(taken[Stage::Fsync.index()], 7);
        assert_eq!(s.take(), [0; Stage::COUNT]);
    }
}
