//! Pure-Rust re-implementations of both model families.
//!
//! These exist for three reasons:
//!
//! 1. **Differential testing** — the PJRT-executed artifacts must agree
//!    with these to within f32 tolerance (see `rust/tests/`), which
//!    validates the entire AOT bridge end-to-end.
//! 2. **Fallback** — environments without built artifacts (e.g. a bare
//!    `cargo test`) still exercise all coordinator logic.
//! 3. **Perf baseline** — the §Perf benches compare PJRT vs native
//!    latency to quantify what the XLA path buys (batch fusion).

use crate::cloud::Cloud;
use crate::models::{ConfigQuery, RuntimeModel};
use crate::repo::featurize::{FeatureSpace, Featurizer};
use crate::repo::RuntimeDataRepo;
use crate::util::matrix::MatF32;
use crate::util::stats;
use anyhow::{bail, Result};

/// Distance assigned to padded rows (must match `ref.PAD_DISTANCE`).
pub const PAD_DISTANCE: f32 = 1e30;

/// Native similarity-weighted kNN (pessimistic model).
#[derive(Debug, Clone)]
pub struct NativeKnn {
    pub space: FeatureSpace,
    pub train_x: MatF32,
    pub train_y: Vec<f32>,
    pub weights: Vec<f32>,
    pub k: usize,
}

impl NativeKnn {
    /// Fit on a repository: standardize, learn correlation weights.
    /// Mirrors `Predictor::train_pessimistic` exactly (same weight floor).
    pub fn fit(cloud: &Cloud, repo: &RuntimeDataRepo, k: usize) -> Result<NativeKnn> {
        if repo.is_empty() {
            bail!("cannot fit on an empty repository");
        }
        let featurizer = Featurizer::new(cloud);
        let (space, x, y) = featurizer.fit(repo);
        let d = space.dim();
        let yf: Vec<f64> = y.iter().map(|&v| v as f64).collect();
        let mut weights = vec![0.0f32; d];
        for c in 0..d {
            let col: Vec<f64> = (0..x.rows).map(|r| x.at(r, c) as f64).collect();
            let corr = stats::pearson(&col, &yf);
            weights[c] = if corr.is_finite() {
                (corr.abs() as f32).max(0.05)
            } else {
                0.05
            };
        }
        Ok(NativeKnn {
            space,
            train_x: x,
            train_y: y,
            weights,
            k,
        })
    }

    /// Predict one standardized query row (in the fitted space).
    pub fn predict_row(&self, row: &[f32]) -> f64 {
        let t = self.train_x.rows;
        let mut dists: Vec<(f32, usize)> = Vec::with_capacity(t);
        for i in 0..t {
            let tr = self.train_x.row(i);
            let mut d = 0.0f32;
            for c in 0..row.len() {
                let diff = row[c] - tr[c];
                d += self.weights[c] * diff * diff;
            }
            dists.push((d, i));
        }
        dists.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let k = self.k.min(t);
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for &(d, i) in dists.iter().take(k) {
            let w = 1.0 / (d as f64 + 1e-6);
            num += w * self.train_y[i] as f64;
            den += w;
        }
        self.space.unscale_runtime((num / den.max(1e-6)) as f32)
    }
}

impl RuntimeModel for NativeKnn {
    fn predict(&mut self, cloud: &Cloud, queries: &[ConfigQuery]) -> Result<Vec<f64>> {
        let featurizer = Featurizer::new(cloud);
        Ok(queries
            .iter()
            .map(|q| {
                let row =
                    featurizer.transform(&self.space, &q.machine, q.scaleout, &q.job_features);
                self.predict_row(&row)
            })
            .collect())
    }
}

/// Native forward pass of the optimistic model (given trained params).
/// Mirrors `optimistic_predict_ref` in Python: bias + [x, log1p(x),
/// 1/(x+0.1)] basis.
#[derive(Debug, Clone)]
pub struct NativeOptimistic {
    pub mins: Vec<f32>,
    pub spans: Vec<f32>,
    pub y_mean: f32,
    pub y_sd: f32,
    pub params: Vec<f32>,
    /// Number of real (unpadded) feature columns.
    pub dim: usize,
}

impl NativeOptimistic {
    /// Build from the trained PJRT model state.
    pub fn from_state(
        mins: &[f32],
        spans: &[f32],
        y_mean: f32,
        y_sd: f32,
        params: &[f32],
        dim: usize,
    ) -> Self {
        NativeOptimistic {
            mins: mins.to_vec(),
            spans: spans.to_vec(),
            y_mean,
            y_sd,
            params: params.to_vec(),
            dim,
        }
    }

    /// Forward pass over scaled features x01 (full padded width).
    pub fn predict_x01(&self, x01: &[f32]) -> f64 {
        let f = self.mins.len();
        debug_assert_eq!(self.params.len(), 1 + 3 * f);
        let mut acc = self.params[0];
        for c in 0..f {
            let x = x01[c];
            acc += self.params[1 + c] * x;
            acc += self.params[1 + f + c] * (1.0 + x).ln();
            acc += self.params[1 + 2 * f + c] / (x + 0.1);
        }
        ((acc * self.y_sd + self.y_mean) as f64).exp()
    }
}

impl RuntimeModel for NativeOptimistic {
    fn predict(&mut self, cloud: &Cloud, queries: &[ConfigQuery]) -> Result<Vec<f64>> {
        let featurizer = Featurizer::new(cloud);
        let f = self.mins.len();
        Ok(queries
            .iter()
            .map(|q| {
                let raw = featurizer.raw_row(&q.machine, q.scaleout, &q.job_features);
                let mut x01 = vec![0.0f32; f];
                for (c, &rv) in raw.iter().enumerate() {
                    x01[c] = (((rv - self.mins[c]) / self.spans[c]).max(-0.05)).min(5.0);
                }
                self.predict_x01(&x01)
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo::RuntimeRecord;
    use crate::workloads::JobKind;

    fn toy_repo() -> RuntimeDataRepo {
        // runtime = 1000 / scaleout (pure scale-out law)
        let mut recs = Vec::new();
        for &n in &[2u32, 4, 6, 8, 10, 12] {
            for m in ["c5.xlarge", "m5.xlarge", "r5.xlarge"] {
                recs.push(RuntimeRecord {
                    job: JobKind::Sort,
                    org: "t".into(),
                    machine: m.into(),
                    scaleout: n,
                    job_features: vec![15.0],
                    runtime_s: 1000.0 / n as f64,
                });
            }
        }
        RuntimeDataRepo::from_records(JobKind::Sort, recs)
    }

    #[test]
    fn knn_exact_training_point() {
        let cloud = Cloud::aws_like();
        let repo = toy_repo();
        let mut knn = NativeKnn::fit(&cloud, &repo, 5).unwrap();
        let qs = vec![ConfigQuery {
            machine: "m5.xlarge".into(),
            scaleout: 4,
            job_features: vec![15.0],
        }];
        let pred = knn.predict(&cloud, &qs).unwrap()[0];
        assert!((pred - 250.0).abs() / 250.0 < 0.02, "pred {pred}");
    }

    #[test]
    fn knn_interpolates_between_scaleouts() {
        let cloud = Cloud::aws_like();
        let repo = toy_repo();
        let mut knn = NativeKnn::fit(&cloud, &repo, 3).unwrap();
        let qs = vec![ConfigQuery {
            machine: "m5.xlarge".into(),
            scaleout: 5,
            job_features: vec![15.0],
        }];
        let pred = knn.predict(&cloud, &qs).unwrap()[0];
        // truth 200; neighbours 250 and 166.7 — prediction in between
        assert!((150.0..280.0).contains(&pred), "pred {pred}");
    }

    #[test]
    fn knn_weights_floor_applied() {
        let cloud = Cloud::aws_like();
        let repo = toy_repo();
        let knn = NativeKnn::fit(&cloud, &repo, 5).unwrap();
        assert!(knn.weights.iter().all(|&w| w >= 0.05));
    }

    #[test]
    fn optimistic_forward_matches_manual() {
        let f = 3;
        let mut params = vec![0.0f32; 1 + 3 * f];
        params[0] = 1.0; // bias
        params[1] = 2.0; // x0 linear
        params[1 + f + 1] = -1.0; // x1 log
        params[1 + 2 * f + 2] = 0.5; // x2 reciprocal
        let m = NativeOptimistic {
            mins: vec![0.0; f],
            spans: vec![1.0; f],
            y_mean: 0.0,
            y_sd: 1.0,
            params,
            dim: f,
        };
        let x01 = vec![0.5f32, 0.3, 0.2];
        let want =
            (1.0 + 2.0 * 0.5 - (1.0f32 + 0.3).ln() + 0.5 / (0.2 + 0.1)) as f64;
        let got = m.predict_x01(&x01).ln();
        assert!((got - want as f64).abs() < 1e-5, "{got} vs {want}");
    }

    #[test]
    fn empty_repo_rejected() {
        let cloud = Cloud::aws_like();
        assert!(NativeKnn::fit(&cloud, &RuntimeDataRepo::new(JobKind::Sort), 5).is_err());
    }
}
