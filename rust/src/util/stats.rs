//! Statistics helpers used across the simulator, the models, and the
//! figure/bench harnesses: robust location estimates (the paper reports the
//! *median of five repetitions* per experiment), error metrics for the
//! prediction models (MAPE/SMAPE), and small least-squares fits used by the
//! figure regenerators (linearity checks, Fig. 4) and the Ernest baseline.

/// Median of a slice (averaging the two middle elements for even length).
/// Returns `NaN` for an empty slice.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Arithmetic mean; `NaN` for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; `NaN` for an empty slice.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated quantile, `q` in `[0, 1]`.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Mean absolute percentage error of predictions vs. true values.
/// Entries with `truth == 0` are skipped.
pub fn mape(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let mut total = 0.0;
    let mut n = 0usize;
    for (&p, &t) in pred.iter().zip(truth) {
        if t != 0.0 {
            total += ((p - t) / t).abs();
            n += 1;
        }
    }
    if n == 0 {
        f64::NAN
    } else {
        100.0 * total / n as f64
    }
}

/// Symmetric MAPE in `[0, 200]`.
pub fn smape(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let mut total = 0.0;
    let mut n = 0usize;
    for (&p, &t) in pred.iter().zip(truth) {
        let denom = (p.abs() + t.abs()) / 2.0;
        if denom > 0.0 {
            total += (p - t).abs() / denom;
            n += 1;
        }
    }
    if n == 0 {
        f64::NAN
    } else {
        100.0 * total / n as f64
    }
}

/// Pearson correlation coefficient of two equal-length series.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return f64::NAN;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// Ordinary least squares fit `y = a + b x`; returns `(a, b, r2)`.
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    assert!(n >= 2.0, "need at least two points");
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
    }
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    // R² against the mean model.
    let ss_tot: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(&x, &y)| (y - (a + b * x)).powi(2))
        .sum();
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    (a, b, r2)
}

/// Multi-feature ordinary least squares via normal equations with ridge
/// damping (`lambda`). `x` is row-major `n × d`; returns `d` coefficients.
/// Used by the Ernest baseline's parametric fit (with non-negativity
/// enforced by projected gradient refinement in the caller).
pub fn ridge_fit(x: &[f64], n: usize, d: usize, y: &[f64], lambda: f64) -> Vec<f64> {
    assert_eq!(x.len(), n * d);
    assert_eq!(y.len(), n);
    // A = XᵀX + λI  (d×d), b = Xᵀy
    let mut a = vec![0.0f64; d * d];
    let mut b = vec![0.0f64; d];
    for i in 0..n {
        let row = &x[i * d..(i + 1) * d];
        for j in 0..d {
            b[j] += row[j] * y[i];
            for k in 0..d {
                a[j * d + k] += row[j] * row[k];
            }
        }
    }
    for j in 0..d {
        a[j * d + j] += lambda;
    }
    solve_dense(&mut a, &mut b, d);
    b
}

/// In-place Gaussian elimination with partial pivoting: solves `A x = b`,
/// leaving the solution in `b`. `a` is row-major `d × d` and is destroyed.
pub fn solve_dense(a: &mut [f64], b: &mut [f64], d: usize) {
    for col in 0..d {
        // pivot
        let mut piv = col;
        let mut best = a[col * d + col].abs();
        for r in (col + 1)..d {
            let v = a[r * d + col].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best < 1e-12 {
            continue; // singular direction; leave as-is (ridge prevents this)
        }
        if piv != col {
            for c in 0..d {
                a.swap(col * d + c, piv * d + c);
            }
            b.swap(col, piv);
        }
        let diag = a[col * d + col];
        for r in (col + 1)..d {
            let f = a[r * d + col] / diag;
            if f == 0.0 {
                continue;
            }
            for c in col..d {
                a[r * d + c] -= f * a[col * d + c];
            }
            b[r] -= f * b[col];
        }
    }
    // back substitution
    for col in (0..d).rev() {
        let diag = a[col * d + col];
        if diag.abs() < 1e-12 {
            b[col] = 0.0;
            continue;
        }
        let mut acc = b[col];
        for c in (col + 1)..d {
            acc -= a[col * d + c] * b[c];
        }
        b[col] = acc / diag;
    }
}

/// Normalized root-mean-square deviation between two curves, used by the
/// Fig. 7 harness to quantify whether a factor changes the *shape* of a
/// scale-out curve (curves are first normalized by their own mean).
pub fn curve_shape_divergence(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    assert!(!a.is_empty());
    let ma = mean(a);
    let mb = mean(b);
    let mut acc = 0.0;
    for i in 0..a.len() {
        let na = a[i] / ma;
        let nb = b[i] / mb;
        acc += (na - nb).powi(2);
    }
    (acc / a.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even_empty() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!(median(&[]).is_nan());
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.25), 2.0);
    }

    #[test]
    fn mape_basic() {
        let e = mape(&[110.0, 90.0], &[100.0, 100.0]);
        assert!((e - 10.0).abs() < 1e-9);
    }

    #[test]
    fn mape_skips_zero_truth() {
        let e = mape(&[110.0, 50.0], &[100.0, 0.0]);
        assert!((e - 10.0).abs() < 1e-9);
    }

    #[test]
    fn smape_symmetric() {
        let a = smape(&[110.0], &[100.0]);
        let b = smape(&[100.0], &[110.0]);
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn linfit_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b, r2) = linfit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn linfit_r2_drops_for_nonlinear() {
        let xs: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x * x).collect();
        let (_, _, r2) = linfit(&xs, &ys);
        assert!(r2 < 0.99, "quadratic should not fit perfectly: {r2}");
    }

    #[test]
    fn ridge_recovers_coefficients() {
        // y = 2 x0 + 3 x1 on a small grid
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..10 {
            for j in 0..10 {
                x.push(i as f64);
                x.push(j as f64);
                y.push(2.0 * i as f64 + 3.0 * j as f64);
            }
        }
        let w = ridge_fit(&x, 100, 2, &y, 1e-9);
        assert!((w[0] - 2.0).abs() < 1e-6, "{w:?}");
        assert!((w[1] - 3.0).abs() < 1e-6, "{w:?}");
    }

    #[test]
    fn solve_dense_identity() {
        let mut a = vec![1.0, 0.0, 0.0, 1.0];
        let mut b = vec![5.0, -3.0];
        solve_dense(&mut a, &mut b, 2);
        assert_eq!(b, vec![5.0, -3.0]);
    }

    #[test]
    fn solve_dense_pivoting() {
        // requires row swap: first pivot is 0
        let mut a = vec![0.0, 1.0, 1.0, 0.0];
        let mut b = vec![2.0, 3.0];
        solve_dense(&mut a, &mut b, 2);
        assert!((b[0] - 3.0).abs() < 1e-12);
        assert!((b[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn shape_divergence_zero_for_scaled_curves() {
        let a = [1.0, 2.0, 4.0];
        let b = [10.0, 20.0, 40.0];
        assert!(curve_shape_divergence(&a, &b) < 1e-12);
        let c = [4.0, 2.0, 1.0];
        assert!(curve_shape_divergence(&a, &c) > 0.1);
    }
}
