//! Baseline cluster-configuration approaches (paper §II related work).
//!
//! The paper positions C3O against two families:
//!
//! * **Iterative search** — profile candidate configurations until
//!   confident: [`cherrypick`] (Bayesian optimization, NSDI'17). Pays
//!   real cluster time per probe (including the ~7 min EMR provisioning
//!   delay the paper highlights).
//! * **Combined profiling** — [`micky`] (IEEE CLOUD'18): profile several
//!   workloads simultaneously, reformulated as a multi-armed bandit, and
//!   recommend one shared configuration.
//! * **Performance models from dedicated profiling** — [`ernest`]
//!   (NSDI'16): run the job on *subsampled* data at a few scale-outs,
//!   fit a parametric scale-out law, predict the full run.
//! * **Folk strategies** — [`naive`]: overprovision-to-the-max, cheapest
//!   hourly rate, or random choice; what users without tooling do.
//!
//! Every baseline implements [`ConfigSearch`] and is charged for its
//! profiling through the [`SimOracle`]'s run accounting, so the benches
//! can report *total cost to decision* — the axis on which C3O's
//! zero-profiling approach wins.

pub mod cherrypick;
pub mod ernest;
pub mod micky;
pub mod naive;

pub use cherrypick::CherryPick;
pub use ernest::Ernest;
pub use micky::{CombinedOutcome, Micky};
pub use naive::{NaiveCheapest, NaiveMax, NaiveRandom};

use crate::cloud::Cloud;
use crate::configurator::JobRequest;
use crate::models::oracle::SimOracle;
use anyhow::Result;

/// The decision any approach ultimately produces.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub machine: String,
    pub scaleout: u32,
    /// The approach's own runtime estimate for its choice (NaN if it
    /// doesn't estimate).
    pub predicted_runtime_s: f64,
    /// Number of profiling executions performed to decide.
    pub profiling_runs: u64,
    /// Dollars burned on profiling (cluster time + provisioning).
    pub profiling_cost_usd: f64,
    /// Wall-clock seconds of profiling (incl. provisioning delays).
    pub profiling_seconds: f64,
}

/// A cluster-configuration approach.
pub trait ConfigSearch {
    fn name(&self) -> &'static str;

    /// Decide a configuration for the request. Profiling (if any) goes
    /// through the oracle, which meters it.
    fn search(
        &mut self,
        cloud: &Cloud,
        oracle: &mut SimOracle,
        request: &JobRequest,
    ) -> Result<SearchOutcome>;
}

/// Helper shared by profiling-based baselines: meter one probe run,
/// charging cluster time + provisioning at the cloud's billing policy.
pub(crate) fn metered_probe(
    cloud: &Cloud,
    oracle: &mut SimOracle,
    machine: &str,
    scaleout: u32,
    job_features: &[f64],
    provisioning_s: f64,
) -> Result<(f64, f64, f64)> {
    let q = crate::models::ConfigQuery {
        machine: machine.to_string(),
        scaleout,
        job_features: job_features.to_vec(),
    };
    let runtime = oracle.run_once(cloud, &q)?;
    let held = runtime + provisioning_s;
    let cost = cloud.cost_usd(machine, scaleout, held);
    Ok((runtime, cost, held))
}
