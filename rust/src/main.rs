//! c3o — command-line interface to the C3O system.
//!
//! ```text
//! c3o corpus     [--seed N] [--out DIR]        generate the 930-run corpus CSVs
//! c3o figures    [--seed N]                    regenerate Table I + Figs 3–7
//! c3o table1 | fig3 | fig4 | fig5 | fig6 | fig7
//! c3o configure  --job J [job args] [--target S] [--seed N] [--json]
//! c3o recommend  --job J [job args] [--target S] [--seed N] [--json]
//! c3o contribute --job J [job args] --machine M --scaleout N --runtime-s T
//!                [--org NAME] [--data DIR] [--json]
//! c3o e2e        [--jobs N] [--seed N]         collaborative end-to-end demo
//! c3o serve      [--workers N] [--clients N] [--jobs N] [--seed N] [--json]
//!                [--trace-out FILE]            sharded multi-org service demo
//! c3o store      --dir DIR [--mode seed|verify|stat] [--seed N]
//!                                              durable segment-store exercise
//! c3o sync       [--max-rounds N] [--seed N] [--store-a DIR] [--store-b DIR]
//!                [--protocol v2|v3|v4] [--json]  two-service federation demo
//! c3o mesh       [--peers N] [--fanout K] [--max-rounds N] [--seed N] [--json]
//!                                              gossip-mesh federation demo
//! ```
//!
//! Argument parsing is hand-rolled (clap is not in the offline vendor
//! set): `--key value` pairs after the subcommand; a `--key` followed by
//! another `--flag` (or the end of the line) is a boolean switch.

use c3o::api::{ApiError, Client};
use c3o::cloud::Cloud;
use c3o::configurator::JobRequest;
use c3o::coordinator::{Coordinator, CoordinatorService, Organization, ServiceConfig};
use c3o::figures;
use c3o::repo::{RuntimeDataRepo, RuntimeRecord};
use c3o::runtime::Runtime;
use c3o::workloads::{ExperimentGrid, JobKind, JobSpec};
use std::collections::HashMap;
use std::path::PathBuf;
use std::process::ExitCode;
use std::time::Instant;

/// Parsed `--key value` arguments.
struct Args {
    flags: HashMap<String, String>,
}

/// Flags that are boolean switches: `--json` alone means `true`. Every
/// other flag still requires a value, so a forgotten value (e.g.
/// `--org --machine ...`) stays a hard error instead of silently
/// becoming the string "true".
const SWITCHES: &[&str] = &["json"];

impl Args {
    fn parse(argv: &[String]) -> Result<Args, String> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                match argv.get(i + 1) {
                    Some(v) if !v.starts_with("--") => {
                        flags.insert(key.to_string(), v.clone());
                        i += 2;
                    }
                    _ if SWITCHES.contains(&key) => {
                        flags.insert(key.to_string(), "true".to_string());
                        i += 1;
                    }
                    _ => return Err(format!("--{key} needs a value")),
                }
            } else {
                return Err(format!("unexpected argument {a:?}"));
            }
        }
        Ok(Args { flags })
    }

    /// Boolean switch: present (with no value or `true`) ⇒ on.
    fn switch(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(String::as_str), Some("true") | Some("1"))
    }

    fn get<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, String> {
        match self.flags.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{key}: cannot parse {v:?}")),
        }
    }

    fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        Ok(self.get(key)?.unwrap_or(default))
    }
}

const USAGE: &str = "c3o — collaborative cluster configuration (C3O reproduction)

USAGE:
  c3o corpus     [--seed N] [--out DIR]       generate the 930-run corpus CSVs
  c3o figures    [--seed N]                   regenerate Table I + Figs 3-7
  c3o table1|fig3|fig4|fig5|fig6|fig7 [--seed N]
  c3o configure  --job sort     --data-gb X
                 --job grep     --data-gb X --ratio R
                 --job sgd      --data-gb X --iters I
                 --job kmeans   --data-gb X --k K [--conv C]
                 --job pagerank --graph-mb X [--conv C]
                 [--target SECONDS] [--seed N] [--json]
                                              full loop: decide + run + contribute
  c3o recommend  --job J [job args as above] [--target SECONDS] [--seed N] [--json]
                                              read-only: score candidates, run nothing
  c3o contribute --job J [job args as above] --machine M --scaleout N --runtime-s T
                 [--org NAME] [--data DIR] [--json]
                                              record an externally-observed run
                                              into DIR/<job>.csv (default data/)
  c3o e2e        [--jobs N] [--seed N]        collaborative end-to-end demo
  c3o serve      [--workers N] [--clients N] [--jobs N] [--seed N] [--json]
                 [--trace-out FILE]           sharded multi-org service demo;
                                              --json emits every metrics counter
                                              plus a `latency` block (per-kind /
                                              per-stage p50/p95/p99 and the
                                              slowest span breakdowns);
                                              --trace-out writes the request
                                              spans as Chrome trace-event JSON
                                              (open in Perfetto)
  c3o store      --dir DIR [--mode seed|verify|stat] [--seed N]
                                              durable segment store: seed it from
                                              the corpus, verify recovery, or stat
  c3o sync       [--max-rounds N] [--seed N] [--store-a DIR] [--store-b DIR]
                 [--protocol v2|v3|v4] [--json]
                                              federation demo: two services with
                                              disjoint org corpora converge via
                                              record-level deltas — per-job v3
                                              SyncPull/SyncPush, the batched v4
                                              cross-job exchange (default), or
                                              the legacy v2 translation; --json
                                              emits per-org exchange stats and
                                              round-trip / wall-time totals
  c3o mesh       [--peers N] [--fanout K] [--max-rounds N] [--seed N] [--json]
                                              gossip-mesh demo: N services join
                                              a roster, anti-entropy rounds pick
                                              fanout-K peers from the live
                                              membership and run the batched v4
                                              exchange until every repository is
                                              bitwise-identical; acked-prefix
                                              op-log truncation runs along the
                                              way (reported as ops_truncated)
";

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        eprint!("{USAGE}");
        return ExitCode::FAILURE;
    };
    match run(cmd, &argv[1..]) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run(cmd: &str, rest: &[String]) -> Result<(), String> {
    let args = Args::parse(rest)?;
    let seed: u64 = args.get_or("seed", 42)?;
    let cloud = Cloud::aws_like();
    match cmd {
        "corpus" => cmd_corpus(&cloud, &args, seed),
        "figures" => {
            for fig in figures::all(&cloud, seed) {
                println!("{}", fig.render());
            }
            Ok(())
        }
        "table1" => {
            println!("{}", figures::table1(&cloud, seed).render());
            Ok(())
        }
        "fig3" => {
            println!("{}", figures::fig3(&cloud, seed).render());
            Ok(())
        }
        "fig4" => {
            println!("{}", figures::fig4(&cloud, seed).render());
            Ok(())
        }
        "fig5" => {
            println!("{}", figures::fig5(&cloud, seed).render());
            Ok(())
        }
        "fig6" => {
            println!("{}", figures::fig6(&cloud, seed).render());
            Ok(())
        }
        "fig7" => {
            println!("{}", figures::fig7(&cloud, seed).render());
            Ok(())
        }
        "configure" => cmd_configure(&cloud, &args, seed),
        "recommend" => cmd_recommend(&cloud, &args, seed),
        "contribute" => cmd_contribute(&cloud, &args),
        "e2e" => cmd_e2e(&cloud, &args, seed),
        "serve" => cmd_serve(&cloud, &args, seed),
        "store" => cmd_store(&cloud, &args, seed),
        "sync" => cmd_sync(&cloud, &args, seed),
        "mesh" => cmd_mesh(&cloud, &args, seed),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command {other:?}\n{USAGE}")),
    }
}

fn cmd_corpus(cloud: &Cloud, args: &Args, seed: u64) -> Result<(), String> {
    let out: PathBuf = PathBuf::from(args.get_or("out", "data".to_string())?);
    eprintln!("executing the 930-experiment grid (5 repetitions each)...");
    let grid = ExperimentGrid::paper_table1();
    let corpus = grid.execute(cloud, seed);
    for kind in JobKind::all() {
        let repo = corpus.repo_for(kind);
        let path = out.join(format!("{}.csv", kind.name()));
        repo.save(&path).map_err(|e| e.to_string())?;
        println!("wrote {:>4} records  {}", repo.len(), path.display());
    }
    Ok(())
}

fn spec_from_args(args: &Args) -> Result<JobSpec, String> {
    let job: String = args
        .get::<String>("job")?
        .ok_or("--job is required".to_string())?;
    let kind = JobKind::parse(&job).ok_or(format!("unknown job {job:?}"))?;
    Ok(match kind {
        JobKind::Sort => JobSpec::sort(args.get_or("data-gb", 15.0)?),
        JobKind::Grep => JobSpec::grep(
            args.get_or("data-gb", 15.0)?,
            args.get_or("ratio", 0.1)?,
        ),
        JobKind::Sgd => JobSpec::sgd(
            args.get_or("data-gb", 20.0)?,
            args.get_or("iters", 100)?,
        ),
        JobKind::KMeans => JobSpec::kmeans(
            args.get_or("data-gb", 15.0)?,
            args.get_or("k", 5)?,
            args.get_or("conv", 0.001)?,
        ),
        JobKind::PageRank => JobSpec::pagerank(
            args.get_or("graph-mb", 330.0)?,
            args.get_or("conv", 0.001)?,
        ),
    })
}

/// Build the shared corpus slice for one job kind (what other
/// organizations have contributed) — the data both `configure` and
/// `recommend` are served from.
fn shared_corpus_for(cloud: &Cloud, kind: JobKind, seed: u64) -> RuntimeDataRepo {
    let grid = ExperimentGrid {
        experiments: ExperimentGrid::paper_table1()
            .experiments
            .into_iter()
            .filter(|e| e.spec.kind() == kind)
            .collect(),
        repetitions: 5,
    };
    grid.execute(cloud, seed).repo_for(kind)
}

fn request_from_args(args: &Args) -> Result<JobRequest, String> {
    let spec = spec_from_args(args)?;
    let mut request = JobRequest::new(spec);
    if let Some(t) = args.get::<f64>("target")? {
        request = request.with_target_seconds(t);
    }
    Ok(request)
}

fn api_err(e: ApiError) -> String {
    e.to_string()
}

fn cmd_configure(cloud: &Cloud, args: &Args, seed: u64) -> Result<(), String> {
    let request = request_from_args(args)?;
    let spec = request.spec.clone();
    let dir = Runtime::default_dir();
    if !Runtime::artifacts_available(&dir) {
        eprintln!("note: PJRT artifacts not built — serving with native models");
    }

    eprintln!("building shared corpus for {}...", spec.kind().name());
    let repo = shared_corpus_for(cloud, spec.kind(), seed);

    let mut coord = Coordinator::new(cloud.clone(), &dir, seed).map_err(api_err)?;
    coord.share(&repo).map_err(api_err)?;
    let org = Organization::new("cli-user");
    let outcome = coord.submit(&org, &request).map_err(api_err)?;

    if args.switch("json") {
        println!("{}", outcome.to_json().pretty());
        return Ok(());
    }
    println!("job:        {} {:?}", spec.kind().name(), spec.job_features());
    if let Some(t) = request.target_s {
        println!("target:     {t:.0} s");
    }
    if let Some(report) = coord.selection_report(spec.kind()) {
        println!(
            "model:      {} (CV MAPE: pessimistic {:.1}%, optimistic {:.1}%)",
            report.chosen.name(),
            report.mape_of(c3o::models::ModelKind::Pessimistic),
            report.mape_of(c3o::models::ModelKind::Optimistic),
        );
    }
    println!("choice:     {} x{}", outcome.machine, outcome.scaleout);
    println!("predicted:  {:.1} s", outcome.predicted_runtime_s);
    println!(
        "actual:     {:.1} s  (error {:.1}%)",
        outcome.actual_runtime_s,
        outcome.prediction_error_pct()
    );
    println!(
        "cost:       ${:.3} (incl. {:.0}s provisioning)",
        outcome.actual_cost_usd, outcome.provisioning_s
    );
    println!("met target: {}", outcome.met_target);
    Ok(())
}

/// Read-only recommendation: the configurator step as a standalone
/// query. Scores every candidate and prints the decision — provisions
/// nothing, runs nothing, contributes nothing. `--json` emits the full
/// `Recommendation` (decision + all scored candidates) for scripting.
fn cmd_recommend(cloud: &Cloud, args: &Args, seed: u64) -> Result<(), String> {
    let request = request_from_args(args)?;
    let kind = request.kind();
    let dir = Runtime::default_dir();
    if !Runtime::artifacts_available(&dir) {
        eprintln!("note: PJRT artifacts not built — serving with native models");
    }

    eprintln!("building shared corpus for {}...", kind.name());
    let repo = shared_corpus_for(cloud, kind, seed);

    let mut coord = Coordinator::new(cloud.clone(), &dir, seed).map_err(api_err)?;
    coord.share(&repo).map_err(api_err)?;
    let rec = coord.recommend(&request).map_err(api_err)?;

    if args.switch("json") {
        println!("{}", rec.to_json().pretty());
        return Ok(());
    }
    println!("job:        {} {:?}", kind.name(), request.spec.job_features());
    if let Some(t) = request.target_s {
        println!("target:     {t:.0} s");
    }
    println!(
        "model:      {} (trained at generation {}, serving generation {})",
        rec.model_used.name(),
        rec.trained_at_generation,
        rec.generation
    );
    println!("choice:     {} x{}", rec.choice.machine_type, rec.choice.node_count);
    println!("predicted:  {:.1} s", rec.choice.predicted_runtime_s);
    println!("est. cost:  ${:.3}", rec.choice.expected_cost_usd);
    println!("met target: {}", rec.choice.meets_target);
    println!(
        "candidates: {} scored (cheapest meeting the target wins)",
        rec.choice.candidates.len()
    );
    Ok(())
}

/// Record an externally-observed run into the on-disk shared repository
/// (`DIR/<job>.csv`) — the capture-and-share step of Fig. 1 for runs
/// executed outside this tool, e.g. on a cluster `c3o recommend` picked.
fn cmd_contribute(cloud: &Cloud, args: &Args) -> Result<(), String> {
    let spec = spec_from_args(args)?;
    let kind = spec.kind();
    let machine: String = args
        .get::<String>("machine")?
        .ok_or("--machine is required".to_string())?;
    if cloud.machine(&machine).is_none() {
        let known: Vec<&str> = cloud.machine_types().iter().map(|m| m.name.as_str()).collect();
        return Err(format!(
            "unknown machine type {machine:?} (catalog: {})",
            known.join(", ")
        ));
    }
    let scaleout: u32 = args
        .get::<u32>("scaleout")?
        .ok_or("--scaleout is required".to_string())?;
    let runtime_s: f64 = args
        .get::<f64>("runtime-s")?
        .ok_or("--runtime-s is required".to_string())?;
    let org: String = args.get_or("org", "cli-user".to_string())?;
    let data_dir = PathBuf::from(args.get_or("data", "data".to_string())?);

    let record = RuntimeRecord {
        job: kind,
        org,
        machine,
        scaleout,
        job_features: spec.job_features(),
        runtime_s,
    };

    // load-or-create the on-disk repository, route the record through
    // the same contribute path a coordinator shard uses, save back
    let path = data_dir.join(format!("{}.csv", kind.name()));
    let mut repo = if path.exists() {
        RuntimeDataRepo::load(kind, &path)?
    } else {
        RuntimeDataRepo::new(kind)
    };
    repo.contribute(record)
        .map_err(|e| format!("invalid record: {e}"))?;
    repo.save(&path).map_err(|e| e.to_string())?;

    let contribution = c3o::api::Contribution {
        job: kind,
        added: 1,
        generation: repo.generation(),
    };
    if args.switch("json") {
        println!("{}", contribution.to_json().pretty());
    } else {
        println!(
            "recorded 1 {} run ({} records total) -> {}",
            kind.name(),
            repo.len(),
            path.display()
        );
    }
    Ok(())
}

fn cmd_e2e(cloud: &Cloud, args: &Args, seed: u64) -> Result<(), String> {
    let jobs: usize = args.get_or("jobs", 10)?;
    let dir = Runtime::default_dir();
    if !Runtime::artifacts_available(&dir) {
        eprintln!("note: PJRT artifacts not built — serving with native models");
    }
    eprintln!("seeding shared repositories from the 930-run corpus...");
    let corpus = ExperimentGrid::paper_table1().execute(cloud, seed);
    let mut coord = Coordinator::new(cloud.clone(), &dir, seed).map_err(api_err)?;
    for kind in JobKind::all() {
        coord.share(&corpus.repo_for(kind)).map_err(api_err)?;
    }
    let org = Organization::new("new-org");
    let requests = [
        JobRequest::sort(17.0).with_target_seconds(400.0),
        JobRequest::grep(12.0, 0.2).with_target_seconds(300.0),
        JobRequest::sgd(25.0, 80).with_target_seconds(900.0),
        JobRequest::kmeans(18.0, 7, 0.001).with_target_seconds(1200.0),
        JobRequest::pagerank(400.0, 0.0005).with_target_seconds(600.0),
    ];
    println!(
        "{:<10} {:>12} {:>5} {:>10} {:>10} {:>7} {:>7}",
        "job", "machine", "n", "pred_s", "actual_s", "err%", "met"
    );
    for i in 0..jobs {
        let req = requests[i % requests.len()].clone();
        let o = coord.submit(&org, &req).map_err(api_err)?;
        println!(
            "{:<10} {:>12} {:>5} {:>10.1} {:>10.1} {:>7.1} {:>7}",
            o.job.name(),
            o.machine,
            o.scaleout,
            o.predicted_runtime_s,
            o.actual_runtime_s,
            o.prediction_error_pct(),
            o.met_target
        );
    }
    let m = coord.metrics();
    println!(
        "\nsubmissions {}  retrains {}  target hit rate {:.0}%  mean prediction error {:.1}%  total cost ${:.2}",
        m.submissions,
        m.retrains,
        100.0 * m.target_hit_rate(),
        m.mean_prediction_error_pct(),
        m.total_cost_usd
    );
    Ok(())
}

/// The multi-org service driver: N worker threads serve interleaved
/// submissions from concurrent client threads across all five job-kind
/// shards, with per-request replies. Works with or without PJRT
/// artifacts (native model fallback).
fn cmd_serve(cloud: &Cloud, args: &Args, seed: u64) -> Result<(), String> {
    let workers: usize = args.get_or("workers", 4)?;
    let clients: usize = args.get_or("clients", 8)?;
    let jobs: usize = args.get_or("jobs", 40)?;
    let trace_out: Option<String> = args.get("trace-out")?;
    if clients == 0 || jobs == 0 {
        return Err("--clients and --jobs must be >= 1".into());
    }

    eprintln!("seeding shared repositories from the corpus grid (1 repetition)...");
    let corpus = ExperimentGrid {
        experiments: ExperimentGrid::paper_table1().experiments,
        repetitions: 1,
    }
    .execute(cloud, seed);

    let service = CoordinatorService::spawn(
        cloud.clone(),
        ServiceConfig::default()
            .with_workers(workers)
            .with_seed(seed)
            .with_artifacts_dir(Runtime::default_dir()),
    );
    for kind in JobKind::all() {
        let shared = service.share(corpus.repo_for(kind)).map_err(api_err)?;
        eprintln!("  {:>9}: {} records shared", kind.name(), shared.added);
    }

    let request_for = |i: usize| -> JobRequest {
        let gb = 10.0 + (i % 10) as f64;
        match i % 5 {
            0 => JobRequest::sort(gb).with_target_seconds(800.0),
            1 => JobRequest::grep(gb, 0.1).with_target_seconds(600.0),
            2 => JobRequest::sgd(gb, 60).with_target_seconds(1500.0),
            3 => JobRequest::kmeans(gb, 5, 0.001).with_target_seconds(2500.0),
            _ => JobRequest::pagerank(25.0 * gb, 0.001).with_target_seconds(1200.0),
        }
    };

    eprintln!(
        "{clients} client threads pipelining {jobs} jobs through {workers} workers..."
    );
    let t0 = Instant::now();
    let errors: Vec<String> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..clients {
            let client = service.client();
            handles.push(scope.spawn(move || {
                let org = Organization::new(&format!("org-{c}"));
                let mut failures = Vec::new();
                // pipeline: dispatch every request as a ticket up front,
                // then collect the outcomes
                let mut tickets = Vec::new();
                let mut i = c;
                while i < jobs {
                    match client.submit_nowait(&org, request_for(i)) {
                        Ok(ticket) => tickets.push((i, ticket)),
                        Err(e) => failures.push(format!("job {i}: {e}")),
                    }
                    i += clients;
                }
                for (i, ticket) in tickets {
                    if let Err(e) = ticket.wait() {
                        failures.push(format!("job {i}: {e}"));
                    }
                }
                failures
            }));
        }
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("client thread panicked"))
            .collect()
    });
    let wall = t0.elapsed().as_secs_f64();
    if let Some(first) = errors.first() {
        return Err(format!("{} submissions failed; first: {first}", errors.len()));
    }

    let m = service.metrics().map_err(api_err)?;
    let report = service.obs_report();
    if args.switch("json") {
        use c3o::util::json::Json;
        let doc = Json::obj(vec![
            ("wall_s", Json::Num(wall)),
            ("throughput_jobs_per_s", Json::Num(jobs as f64 / wall)),
            ("metrics", m.to_json()),
            ("latency", report.to_json()),
        ]);
        println!("{}", doc.pretty());
    } else {
        use c3o::obs::{ReqKind, Stage};
        println!("jobs served:        {}", m.submissions);
        println!("wall clock:         {wall:.2} s");
        println!("throughput:         {:.1} submissions/s", jobs as f64 / wall);
        println!("model retrains:     {}", m.retrains);
        println!(
            "retrain wall time:  {:.2} s",
            m.retrain_nanos_total as f64 / 1e9
        );
        println!(
            "  featurize:        {:.2} s",
            report.lat.stage_sum_ns(Stage::Featurize) as f64 / 1e9
        );
        println!(
            "  cross-validate:   {:.2} s",
            report.lat.stage_sum_ns(Stage::CrossValidate) as f64 / 1e9
        );
        println!(
            "  winner fit:       {:.2} s",
            report.lat.stage_sum_ns(Stage::WinnerFit) as f64 / 1e9
        );
        println!("feat. rows reused:  {}", m.featurized_rows_reused);
        println!("model cache hits:   {}", m.cache_hits);
        println!("coalesced writes:   {} batches", m.coalesced_write_batches);
        println!("target hit rate:    {:.0}%", 100.0 * m.target_hit_rate());
        println!("mean pred. error:   {:.1}%", m.mean_prediction_error_pct());
        println!("total cost:         ${:.2}", m.total_cost_usd);
        if !report.is_empty() {
            println!("request latency, p50 / p95 / p99 (ms):");
            for kind in ReqKind::ALL {
                let h = report.lat.cell(kind, Stage::Total);
                if h.count() == 0 {
                    continue;
                }
                println!(
                    "  {:<10}  {:>8.2} / {:>8.2} / {:>8.2}   ({} traces)",
                    kind.name(),
                    h.percentile_ns(50) as f64 / 1e6,
                    h.percentile_ns(95) as f64 / 1e6,
                    h.percentile_ns(99) as f64 / 1e6,
                    h.count()
                );
            }
        }
    }
    if let Some(path) = trace_out {
        let doc = service.trace_export_json();
        std::fs::write(&path, doc.pretty())
            .map_err(|e| format!("writing trace to {path}: {e}"))?;
        eprintln!("chrome trace written to {path} (open via ui.perfetto.dev)");
    }
    service.shutdown();
    Ok(())
}

/// Exercise the durable segment store. `--mode seed` writes the corpus
/// through a store-backed coordinator (the real write path: WAL append
/// per applied record); `--mode verify` reopens the store cold and
/// diffs the recovered repositories against a regenerated corpus —
/// exiting nonzero on any loss, duplication, or generation drift;
/// `--mode stat` prints what the store holds. The seed→kill→verify
/// sequence is the CI crash-recovery exercise.
fn cmd_store(cloud: &Cloud, args: &Args, seed: u64) -> Result<(), String> {
    let dir = PathBuf::from(
        args.get::<String>("dir")?
            .ok_or("--dir is required".to_string())?,
    );
    let mode: String = args.get_or("mode", "stat".to_string())?;
    match mode.as_str() {
        "seed" => {
            eprintln!("seeding store at {} from the corpus grid (1 repetition)...", dir.display());
            let corpus = ExperimentGrid {
                experiments: ExperimentGrid::paper_table1().experiments,
                repetitions: 1,
            }
            .execute(cloud, seed);
            let mut coord =
                Coordinator::open_with_store(cloud.clone(), &Runtime::default_dir(), seed, &dir)
                    .map_err(api_err)?;
            // persistence exercise, not model serving: skip training
            coord.min_records = usize::MAX;
            for kind in JobKind::all() {
                let shared = coord.share(&corpus.repo_for(kind)).map_err(api_err)?;
                println!(
                    "  {:>9}: {:>4} records appended, generation {}",
                    kind.name(),
                    shared.added,
                    shared.generation
                );
            }
            println!("seeded (WAL only — no compaction; verify replays it)");
            Ok(())
        }
        "verify" => {
            eprintln!("regenerating the corpus grid to diff against...");
            let corpus = ExperimentGrid {
                experiments: ExperimentGrid::paper_table1().experiments,
                repetitions: 1,
            }
            .execute(cloud, seed);
            let mut failures = Vec::new();
            for kind in JobKind::all() {
                let mut expected = RuntimeDataRepo::new(kind);
                expected
                    .merge(&corpus.repo_for(kind))
                    .map_err(|e| format!("building expected repo: {e}"))?;
                let (store, recovered) =
                    c3o::store::JobStore::open(&dir, kind).map_err(|e| format!("{e:#}"))?;
                let records_ok = recovered.canonical_records() == expected.canonical_records();
                let gen_ok = recovered.generation() == expected.generation();
                println!(
                    "  {:>9}: {:>4} records, generation {:>4}, pending ops {:>4}  {}",
                    kind.name(),
                    recovered.len(),
                    recovered.generation(),
                    store.pending_ops(),
                    if records_ok && gen_ok { "OK" } else { "MISMATCH" }
                );
                if !records_ok {
                    failures.push(format!(
                        "{}: recovered {} records != expected {}",
                        kind.name(),
                        recovered.len(),
                        expected.len()
                    ));
                }
                if !gen_ok {
                    failures.push(format!(
                        "{}: recovered generation {} != expected {}",
                        kind.name(),
                        recovered.generation(),
                        expected.generation()
                    ));
                }
            }
            if failures.is_empty() {
                println!("store recovery verified: no loss, no duplication");
                Ok(())
            } else {
                Err(format!("store recovery FAILED: {}", failures.join("; ")))
            }
        }
        "stat" => {
            for kind in JobKind::all() {
                let (store, recovered) =
                    c3o::store::JobStore::open(&dir, kind).map_err(|e| format!("{e:#}"))?;
                println!(
                    "  {:>9}: {:>4} records, generation {:>4}, snapshot at {:>4}, pending ops {:>4}",
                    kind.name(),
                    recovered.len(),
                    recovered.generation(),
                    store.snapshot_generation(),
                    store.pending_ops()
                );
            }
            Ok(())
        }
        other => Err(format!("unknown --mode {other:?} (seed|verify|stat)")),
    }
}

/// Federation demo: two coordinator services are fed *disjoint* halves
/// of the corpus (organizations "org-alpha" and "org-beta"), then
/// exchange record-level deltas via `SyncPull`/`SyncPush` until
/// quiescent. The demo verifies the convergence contract — identical
/// generations, identical content digests, and bitwise-identical
/// `Recommend` decisions — and exits nonzero if any of it fails.
/// `--store-a`/`--store-b` make the two services durable; `--json`
/// emits the exchange stats (records offered/applied/skipped per org)
/// instead of the prose report.
fn cmd_sync(cloud: &Cloud, args: &Args, seed: u64) -> Result<(), String> {
    let max_rounds: usize = args.get_or("max-rounds", 6)?;
    let json_out = args.switch("json");
    let protocol_name: String = args.get_or("protocol", "v4".to_string())?;
    let protocol = match protocol_name.as_str() {
        "v2" => c3o::store::SyncProtocol::V2,
        "v3" => c3o::store::SyncProtocol::V3,
        "v4" | "batched" => c3o::store::SyncProtocol::BatchedV4,
        other => return Err(format!("unknown --protocol {other:?} (v2|v3|v4)")),
    };
    eprintln!("building disjoint org corpora from the corpus grid (1 repetition)...");
    let corpus = ExperimentGrid {
        experiments: ExperimentGrid::paper_table1().experiments,
        repetitions: 1,
    }
    .execute(cloud, seed);

    let relabel = |records: &[RuntimeRecord], org: &str| -> Vec<RuntimeRecord> {
        records.iter().map(|r| r.with_org(org)).collect()
    };

    let mut config_a = ServiceConfig::default()
        .with_workers(2)
        .with_pjrt_workers(0)
        .with_seed(seed);
    let mut config_b = ServiceConfig::default()
        .with_workers(2)
        .with_pjrt_workers(0)
        .with_seed(seed.wrapping_add(1));
    if let Some(dir) = args.get::<String>("store-a")? {
        config_a = config_a.with_store_dir(PathBuf::from(dir));
    }
    if let Some(dir) = args.get::<String>("store-b")? {
        config_b = config_b.with_store_dir(PathBuf::from(dir));
    }
    let service_a = CoordinatorService::open(cloud.clone(), config_a).map_err(api_err)?;
    let service_b = CoordinatorService::open(cloud.clone(), config_b).map_err(api_err)?;

    let kinds = JobKind::all();
    for kind in kinds {
        let records = corpus.repo_for(kind).records().to_vec();
        let half = records.len() / 2;
        let repo_a =
            RuntimeDataRepo::from_records(kind, relabel(&records[..half], "org-alpha"));
        let repo_b =
            RuntimeDataRepo::from_records(kind, relabel(&records[half..], "org-beta"));
        eprintln!(
            "  {:>9}: alpha holds {}, beta holds {}",
            kind.name(),
            repo_a.len(),
            repo_b.len()
        );
        service_a.share(repo_a).map_err(api_err)?;
        service_b.share(repo_b).map_err(api_err)?;
    }

    let mut client_a = service_a.client();
    let mut client_b = service_b.client();
    let mut total = c3o::store::SyncStats::default();
    let mut by_job: std::collections::BTreeMap<JobKind, c3o::store::OrgExchangeMap> =
        Default::default();
    let options = c3o::store::SyncOptions {
        scope: c3o::store::SyncScope::All,
        detail: c3o::store::SyncDetail::PerOrg,
        protocol,
    };
    let mut rounds = 0usize;
    loop {
        rounds += 1;
        let summary =
            c3o::store::sync(&mut client_a, &mut client_b, &options).map_err(api_err)?;
        total.fold(&summary.stats);
        for (kind, orgs) in &summary.by_job {
            c3o::store::fold_orgs(by_job.entry(*kind).or_default(), orgs);
        }
        eprintln!(
            "round {rounds}: {} records in, {} out, {} skipped, {} conflicts, {} round trips",
            summary.stats.records_in,
            summary.stats.records_out,
            summary.stats.skipped,
            summary.stats.conflicts,
            summary.stats.round_trips
        );
        if summary.stats.quiescent() {
            break;
        }
        if rounds >= max_rounds {
            return Err(format!("no quiescence after {max_rounds} sync rounds"));
        }
    }

    let probe = |kind: JobKind| -> JobRequest {
        match kind {
            JobKind::Sort => JobRequest::sort(14.0),
            JobKind::Grep => JobRequest::grep(14.0, 0.1),
            JobKind::Sgd => JobRequest::sgd(20.0, 60),
            JobKind::KMeans => JobRequest::kmeans(15.0, 5, 0.001),
            JobKind::PageRank => JobRequest::pagerank(330.0, 0.001),
        }
    };

    let mut failures = Vec::new();
    for kind in kinds {
        let info_a = client_a.snapshot_info(kind).map_err(api_err)?;
        let info_b = client_b.snapshot_info(kind).map_err(api_err)?;
        let digest_a = service_a.repo_snapshot(kind).content_digest();
        let digest_b = service_b.repo_snapshot(kind).content_digest();
        let rec_a = client_a.recommend(probe(kind)).map_err(api_err)?;
        let rec_b = client_b.recommend(probe(kind)).map_err(api_err)?;
        let decisions_match = rec_a.choice.machine_type == rec_b.choice.machine_type
            && rec_a.choice.node_count == rec_b.choice.node_count
            && rec_a.choice.predicted_runtime_s.to_bits()
                == rec_b.choice.predicted_runtime_s.to_bits();
        let converged =
            info_a.generation == info_b.generation && digest_a == digest_b && decisions_match;
        if !json_out {
            println!(
                "  {:>9}: gen {}/{}  digest {}  decision {} ({} x{})",
                kind.name(),
                info_a.generation,
                info_b.generation,
                if digest_a == digest_b { "match" } else { "MISMATCH" },
                if decisions_match { "match" } else { "MISMATCH" },
                rec_a.choice.machine_type,
                rec_a.choice.node_count,
            );
        }
        if !converged {
            failures.push(kind.name().to_string());
        }
    }
    service_a.shutdown();
    service_b.shutdown();
    if json_out {
        use c3o::util::json::Json;
        let jobs: Vec<Json> = by_job
            .iter()
            .map(|(kind, orgs)| {
                let org_rows: Vec<Json> = orgs
                    .iter()
                    .map(|(org, x)| {
                        Json::obj(vec![
                            ("org", Json::Str(org.clone())),
                            ("offered", Json::Num(x.offered as f64)),
                            ("applied", Json::Num(x.applied as f64)),
                            ("skipped", Json::Num(x.skipped as f64)),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    ("job", Json::Str(kind.name().to_string())),
                    ("orgs", Json::Arr(org_rows)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("api_version", Json::Num(c3o::api::API_VERSION as f64)),
            ("protocol", Json::Str(protocol_name.clone())),
            ("rounds", Json::Num(rounds as f64)),
            ("converged", Json::Bool(failures.is_empty())),
            (
                "totals",
                Json::obj(vec![
                    ("offered", Json::Num(total.offered as f64)),
                    (
                        "applied",
                        Json::Num((total.records_in + total.records_out) as f64),
                    ),
                    ("skipped", Json::Num(total.skipped as f64)),
                    ("conflicts", Json::Num(total.conflicts as f64)),
                    ("pulls", Json::Num(total.pulls as f64)),
                    ("round_trips", Json::Num(total.round_trips as f64)),
                    ("snapshots", Json::Num(total.snapshots as f64)),
                    ("pull_ms", Json::Num(total.pull_nanos as f64 / 1e6)),
                    ("push_ms", Json::Num(total.push_nanos as f64 / 1e6)),
                ]),
            ),
            ("jobs", Json::Arr(jobs)),
        ]);
        println!("{}", doc.pretty());
    } else {
        println!(
            "\nsynced in {rounds} round(s) over {protocol_name}: {} records exchanged ({} offered, {} skipped), {} conflicts, {} round trips",
            total.records_in + total.records_out,
            total.offered,
            total.skipped,
            total.conflicts,
            total.round_trips
        );
        println!(
            "exchange wall time: {:.1} ms pulling, {:.1} ms pushing",
            total.pull_nanos as f64 / 1e6,
            total.push_nanos as f64 / 1e6
        );
    }
    if failures.is_empty() {
        if !json_out {
            println!("federation converged: identical repos, identical decisions");
        }
        Ok(())
    } else {
        Err(format!("peers diverged on: {}", failures.join(", ")))
    }
}

/// Gossip-mesh federation demo: `--peers N` services each hold a
/// disjoint slice of the corpus (organizations `org-0..org-N`), join
/// one roster, and run anti-entropy rounds — each round every peer
/// self-ticks (advancing its round counter, evicting stale members,
/// folding acked op-log prefixes below the truncation floor) and runs
/// the batched v4 cross-job exchange with `--fanout K` peers picked
/// from its **live roster**, not a static list. The demo verifies the
/// convergence contract across all N peers (identical repository
/// digests and bitwise-identical decisions) and reports how many log
/// ops the acked floor let each deployment truncate along the way.
fn cmd_mesh(cloud: &Cloud, args: &Args, seed: u64) -> Result<(), String> {
    let peers_n: usize = args.get_or("peers", 3)?;
    let fanout: usize = args.get_or("fanout", 1)?;
    let max_rounds: usize = args.get_or("max-rounds", 16)?;
    let json_out = args.switch("json");
    if peers_n < 2 {
        return Err("--peers must be >= 2".into());
    }
    if fanout == 0 {
        return Err("--fanout must be >= 1".into());
    }

    eprintln!("building disjoint org corpora from the corpus grid (1 repetition)...");
    let corpus = ExperimentGrid {
        experiments: ExperimentGrid::paper_table1().experiments,
        repetitions: 1,
    }
    .execute(cloud, seed);

    let names: Vec<String> = (0..peers_n).map(|i| format!("peer-{i}")).collect();
    let services: Vec<CoordinatorService> = (0..peers_n)
        .map(|i| {
            CoordinatorService::open(
                cloud.clone(),
                ServiceConfig::default()
                    .with_workers(2)
                    .with_pjrt_workers(0)
                    .with_seed(seed.wrapping_add(i as u64))
                    .with_mesh_name(&names[i]),
            )
        })
        .collect::<Result<_, _>>()
        .map_err(api_err)?;

    // record r of each job's corpus goes to peer r % N, relabeled org-<i>
    for kind in JobKind::all() {
        let records = corpus.repo_for(kind).records().to_vec();
        for (i, service) in services.iter().enumerate() {
            let slice: Vec<RuntimeRecord> = records
                .iter()
                .enumerate()
                .filter(|(r, _)| r % peers_n == i)
                .map(|(_, rec)| rec.with_org(&format!("org-{i}")))
                .collect();
            service
                .share(RuntimeDataRepo::from_records(kind, slice))
                .map_err(api_err)?;
        }
    }

    // join: every peer announces itself to every other, seeding the
    // rosters (from then on membership travels by gossip)
    let intro: Vec<c3o::api::MeshPeer> =
        names.iter().map(|n| c3o::store::mesh_peer(n)).collect();
    let mut clients: Vec<_> = services.iter().map(|s| s.client()).collect();
    for i in 0..peers_n {
        for j in 0..peers_n {
            if i == j {
                continue;
            }
            clients[i]
                .mesh_hello(c3o::api::MeshHello {
                    from: intro[j].clone(),
                    known: intro.clone(),
                    acked: Vec::new(),
                })
                .map_err(api_err)?;
        }
    }

    let mut rounds = 0usize;
    let mut peer_round_trips = 0u64;
    loop {
        rounds += 1;
        let mut round_changed = 0u64;
        for (i, service) in services.iter().enumerate() {
            let mut local = service.client();
            let mut others: Vec<(String, c3o::coordinator::ServiceClient)> = (0..peers_n)
                .filter(|j| *j != i)
                .map(|j| (names[j].clone(), services[j].client()))
                .collect();
            let mut refs: Vec<(String, &mut dyn Client)> = others
                .iter_mut()
                .map(|(name, client)| (name.clone(), client as &mut dyn Client))
                .collect();
            let report =
                c3o::store::mesh_round(&mut local, &mut refs, fanout).map_err(api_err)?;
            round_changed += report.changed;
            peer_round_trips += report.peer_round_trips;
        }
        eprintln!("round {rounds}: {round_changed} holdings changed");
        let converged = JobKind::all().into_iter().all(|kind| {
            let digest = services[0].repo_snapshot(kind).content_digest();
            services[1..]
                .iter()
                .all(|s| s.repo_snapshot(kind).content_digest() == digest)
        });
        if converged && round_changed == 0 {
            break;
        }
        if rounds >= max_rounds {
            return Err(format!("no convergence after {max_rounds} mesh rounds"));
        }
    }

    // the convergence contract, decision-level: every peer answers a
    // probe with bitwise-identical predictions
    let probe = |kind: JobKind| -> JobRequest {
        match kind {
            JobKind::Sort => JobRequest::sort(14.0),
            JobKind::Grep => JobRequest::grep(14.0, 0.1),
            JobKind::Sgd => JobRequest::sgd(20.0, 60),
            JobKind::KMeans => JobRequest::kmeans(15.0, 5, 0.001),
            JobKind::PageRank => JobRequest::pagerank(330.0, 0.001),
        }
    };
    let mut failures = Vec::new();
    for kind in JobKind::all() {
        let first = clients[0].recommend(probe(kind)).map_err(api_err)?;
        let all_match = clients[1..].iter().try_fold(true, |acc, client| {
            let rec = client.recommend(probe(kind)).map_err(api_err)?;
            Ok::<bool, String>(
                acc && rec.choice.machine_type == first.choice.machine_type
                    && rec.choice.node_count == first.choice.node_count
                    && rec.choice.predicted_runtime_s.to_bits()
                        == first.choice.predicted_runtime_s.to_bits(),
            )
        })?;
        if !json_out {
            println!(
                "  {:>9}: decision {} ({} x{})",
                kind.name(),
                if all_match { "match" } else { "MISMATCH" },
                first.choice.machine_type,
                first.choice.node_count,
            );
        }
        if !all_match {
            failures.push(kind.name().to_string());
        }
    }

    let roster = clients[0].mesh_roster().map_err(api_err)?;
    let mut mesh_hellos = 0u64;
    let mut ops_truncated = 0u64;
    for service in &services {
        let m = service.metrics().map_err(api_err)?;
        mesh_hellos += m.mesh_hellos;
        ops_truncated += m.ops_truncated;
    }
    for service in services {
        service.shutdown();
    }

    if json_out {
        use c3o::util::json::Json;
        let peers_json: Vec<Json> = roster
            .peers
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("name", Json::Str(p.peer.name.clone())),
                    ("live", Json::Bool(p.live)),
                    ("last_seen_round", Json::Num(p.last_seen_round as f64)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("api_version", Json::Num(c3o::api::API_VERSION as f64)),
            ("peers", Json::Num(peers_n as f64)),
            ("fanout", Json::Num(fanout as f64)),
            ("rounds", Json::Num(rounds as f64)),
            ("converged", Json::Bool(failures.is_empty())),
            ("peer_round_trips", Json::Num(peer_round_trips as f64)),
            ("mesh_hellos", Json::Num(mesh_hellos as f64)),
            ("ops_truncated", Json::Num(ops_truncated as f64)),
            ("roster", Json::Arr(peers_json)),
        ]);
        println!("{}", doc.pretty());
    } else {
        println!(
            "\nmesh of {peers_n} converged in {rounds} round(s) at fanout {fanout}: {peer_round_trips} peer round trips, {mesh_hellos} hellos"
        );
        println!(
            "acked-floor truncation folded {ops_truncated} op-log entries into base snapshots"
        );
        println!(
            "roster of {}: round {}, {} peers ({} live)",
            roster.local.name,
            roster.round,
            roster.peers.len(),
            roster.peers.iter().filter(|p| p.live).count()
        );
    }
    if failures.is_empty() {
        if !json_out {
            println!("mesh converged: identical repos, identical decisions");
        }
        Ok(())
    } else {
        Err(format!("peers diverged on: {}", failures.join(", ")))
    }
}
