//! Threaded coordinator session: the **legacy single-worker** deployment
//! shape, kept as the baseline the sharded [`super::service`] is
//! benchmarked against (`benches/serve_throughput.rs`).
//!
//! One dedicated worker thread owns a whole [`Coordinator`] (and its
//! model engine — the PJRT client is not `Send`); clients talk to it
//! through a strictly-ordered request/reply channel pair carrying the
//! typed [`crate::api`] protocol. That ordering is the shape's
//! scalability ceiling: every client's reply waits behind every earlier
//! request, across *all* job kinds — reads included, which is exactly
//! what the service's read/write split removes.

// Serving zone: unwraps are outages. The module-scoped clippy
// promotion mirrors the repo lint's `no-panic-serving` rule
// (see rust/lint).
#![cfg_attr(not(test), warn(clippy::unwrap_used))]

use crate::api::{ApiError, Client, Contribution, Recommendation, Request, Response, SnapshotInfo};
use crate::cloud::Cloud;
use crate::configurator::JobRequest;
use crate::coordinator::{Coordinator, JobOutcome, Metrics, Organization};
use crate::repo::{RuntimeDataRepo, RuntimeRecord};
use crate::workloads::JobKind;
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;

/// Requests accepted by the session worker: the protocol, plus shutdown.
enum Event {
    /// One protocol request, answered in order.
    Api(Box<Request>),
    /// Stop the worker.
    Shutdown,
}

/// Replies from the worker (one per event, in order).
enum Reply {
    Api(Box<Result<Response, ApiError>>),
    ShuttingDown,
}

/// Handle to a running session.
pub struct Session {
    tx: mpsc::Sender<Event>,
    rx: mpsc::Receiver<Reply>,
    handle: Option<JoinHandle<()>>,
}

impl Session {
    /// Spawn the worker thread. It constructs the coordinator (and the
    /// PJRT client) on its own thread; construction errors surface on the
    /// first request.
    pub fn spawn(cloud: Cloud, artifacts_dir: PathBuf, seed: u64) -> Session {
        let (tx, worker_rx) = mpsc::channel::<Event>();
        let (worker_tx, rx) = mpsc::channel::<Reply>();
        let handle = std::thread::spawn(move || {
            // Construction is infallible: `Engine::auto` falls back to the
            // native model engines when PJRT artifacts are absent or
            // unloadable, so there is no error path to serve here.
            let mut coord = Coordinator::new(cloud, &artifacts_dir, seed)
                // c3o-lint: allow(no-panic-serving) — `Engine::auto` has a native fallback, so `new` cannot fail; a panic here would mean that contract broke and surfaces as `ApiError::Stopped` on the first call
                .expect("coordinator construction is infallible (native fallback)");
            while let Ok(event) = worker_rx.recv() {
                match event {
                    Event::Api(request) => {
                        let result = coord.call(*request);
                        let _ = worker_tx.send(Reply::Api(Box::new(result)));
                    }
                    Event::Shutdown => {
                        let _ = worker_tx.send(Reply::ShuttingDown);
                        break;
                    }
                }
            }
        });
        Session {
            tx,
            rx,
            handle: Some(handle),
        }
    }

    /// Execute one protocol request; blocks for the (ordered) reply.
    pub fn call(&self, request: Request) -> Result<Response, ApiError> {
        self.tx
            .send(Event::Api(Box::new(request)))
            .map_err(|_| ApiError::Stopped)?;
        match self.rx.recv() {
            Ok(Reply::Api(result)) => *result,
            Ok(Reply::ShuttingDown) | Err(_) => Err(ApiError::Stopped),
        }
    }

    /// Share runtime data; blocks for the worker's reply.
    pub fn share(&self, repo: RuntimeDataRepo) -> Result<Contribution, ApiError> {
        let mut this = self;
        Client::share(&mut this, repo)
    }

    /// Submit a job; blocks for the outcome.
    pub fn submit(&self, org: &Organization, request: JobRequest) -> Result<JobOutcome, ApiError> {
        let mut this = self;
        Client::submit(&mut this, org, request)
    }

    /// Read-only configuration recommendation.
    pub fn recommend(&self, request: JobRequest) -> Result<Recommendation, ApiError> {
        let mut this = self;
        Client::recommend(&mut this, request)
    }

    /// Record one externally-observed run.
    pub fn contribute(&self, record: RuntimeRecord) -> Result<Contribution, ApiError> {
        let mut this = self;
        Client::contribute(&mut this, record)
    }

    /// Fetch a metrics snapshot.
    pub fn metrics(&self) -> Result<Metrics, ApiError> {
        let mut this = self;
        Client::metrics(&mut this)
    }

    /// Describe the model snapshot serving a job's reads.
    pub fn snapshot_info(&self, job: JobKind) -> Result<SnapshotInfo, ApiError> {
        let mut this = self;
        Client::snapshot_info(&mut this, job)
    }

    /// Graceful shutdown (also runs on drop).
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if let Some(handle) = self.handle.take() {
            let _ = self.tx.send(Event::Shutdown);
            // drain until the worker acknowledges or hangs up
            loop {
                match self.rx.recv() {
                    Ok(Reply::ShuttingDown) | Err(_) => break,
                    Ok(_) => continue,
                }
            }
            let _ = handle.join();
        }
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// The session is a [`Client`]: one ordered pipe speaking the protocol.
/// (Implemented on `&Session` too, so a shared session handle can serve
/// the trait's `&mut self` methods without interior mutability — every
/// call is one channel round trip.)
impl Client for &Session {
    fn call(&mut self, request: Request) -> Result<Response, ApiError> {
        Session::call(*self, request)
    }
}

impl Client for Session {
    fn call(&mut self, request: Request) -> Result<Response, ApiError> {
        Session::call(self, request)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use crate::workloads::{ExperimentGrid, JobKind};

    #[test]
    fn session_round_trip() {
        // Runs with or without PJRT artifacts: the coordinator falls
        // back to the native model engines when they are absent.
        let dir = Runtime::default_dir();
        let cloud = Cloud::aws_like();
        // share a corpus slice, then submit through the thread boundary
        let grid = ExperimentGrid {
            experiments: ExperimentGrid::paper_table1()
                .experiments
                .into_iter()
                .filter(|e| e.spec.kind() == JobKind::Sort)
                .collect(),
            repetitions: 1,
        };
        let repo = grid.execute(&cloud, 5).repo_for(JobKind::Sort);

        let session = Session::spawn(cloud, dir, 9);
        let shared = session.share(repo).unwrap();
        assert_eq!(shared.added, 126);
        let org = Organization::new("threaded-org");
        let outcome = session
            .submit(&org, JobRequest::sort(15.0).with_target_seconds(1000.0))
            .unwrap();
        assert!(outcome.model_used.is_some());
        // the read half works through the same ordered pipe
        let rec = session.recommend(JobRequest::sort(15.0)).unwrap();
        assert!(rec.choice.predicted_runtime_s > 0.0);
        let info = session.snapshot_info(JobKind::Sort).unwrap();
        assert_eq!(info.records, 127, "corpus + the submitted run");
        let metrics = session.metrics().unwrap();
        assert_eq!(metrics.submissions, 1);
        assert_eq!(metrics.recommends, 1);
        session.shutdown();
    }

    #[test]
    fn session_falls_back_to_native_without_artifacts() {
        // A missing artifacts directory is not fatal: the coordinator
        // serves the full loop on the native model engines.
        let cloud = Cloud::aws_like();
        let session = Session::spawn(cloud, PathBuf::from("/nonexistent/artifacts"), 1);
        let org = Organization::new("o");
        let outcome = session.submit(&org, JobRequest::sort(10.0)).unwrap();
        assert!(outcome.model_used.is_none(), "cold start overprovisions");
        assert!(outcome.actual_runtime_s > 0.0);
        let metrics = session.metrics().unwrap();
        assert_eq!(metrics.submissions, 1);
        assert_eq!(metrics.fallbacks, 1);
        session.shutdown();
    }

    #[test]
    fn stopped_session_errors_with_typed_stopped() {
        let cloud = Cloud::aws_like();
        let session = Session::spawn(cloud, PathBuf::from("/nonexistent/artifacts"), 2);
        // shut the worker down out from under a second handle path: take
        // the worker down, then call — must be ApiError::Stopped, not a
        // hang or a protocol error
        let _ = session.tx.send(Event::Shutdown);
        // drain the acknowledgement so the reply channel is empty
        loop {
            match session.rx.recv() {
                Ok(Reply::ShuttingDown) | Err(_) => break,
                Ok(_) => continue,
            }
        }
        let err = session.metrics().unwrap_err();
        assert_eq!(err, ApiError::Stopped);
    }
}
