//! The runtime-data repository — the collaborative core of C3O.
//!
//! The paper's idea (§III): runtime data is shared *alongside the code* of
//! a job, so a new user benefits from every execution anyone ever
//! contributed. This module implements that repository:
//!
//! * [`RuntimeRecord`] — one shared observation: which job, on what
//!   cluster (machine type + scale-out), with which dataset
//!   characteristics and parameters, and the resulting runtime (median of
//!   repetitions, matching the paper's protocol). Records carry the
//!   contributing organization for provenance.
//! * [`RuntimeDataRepo`] — a per-job collection with CSV persistence
//!   (the "runtime data repository" of Fig. 2), deduplication, and
//!   **fork/merge** versioning in the style of DataHub/DVC (§III-C).
//! * [`sampling`] — the paper's proposed mitigation when the shared
//!   dataset grows too large: download only a *coverage-preserving
//!   sample* of bounded size (farthest-point sampling in feature space).
//! * [`featurize`] — turns records into model-ready matrices: job
//!   features + scale-out + machine descriptors, z-scored.

pub mod featurize;
pub mod sampling;

pub use featurize::{FeatureSpace, Featurizer};

use crate::util::csv::Table;
use crate::workloads::JobKind;
use std::collections::BTreeSet;
use std::path::Path;

/// One shared runtime observation.
#[derive(Debug, Clone, PartialEq)]
pub struct RuntimeRecord {
    pub job: JobKind,
    /// Contributing organization (provenance; "emulated collaborator").
    pub org: String,
    /// Machine type name, resolvable in the cloud catalog.
    pub machine: String,
    /// Horizontal scale-out (worker count).
    pub scaleout: u32,
    /// Job-specific features, aligned with `JobKind::feature_names()`.
    pub job_features: Vec<f64>,
    /// Median runtime over the repetitions, seconds.
    pub runtime_s: f64,
}

/// Canonical text form of one feature value for [`RuntimeRecord::config_key`].
///
/// Float formatting alone is not a stable identity: `-0.0` and `0.0` are
/// equal grid points but format differently under `{:.6e}`, and the 2^52
/// NaN payloads all denote the same (invalid) point. Normalize before
/// formatting so equal configurations can never produce distinct keys.
fn canonical_feature(f: f64) -> String {
    if f.is_nan() {
        return "nan".to_string();
    }
    let f = if f == 0.0 { 0.0 } else { f }; // collapse -0.0 into 0.0
    format!("{f:.6e}")
}

impl RuntimeRecord {
    /// Stable identity key for deduplication: everything except runtime
    /// and org (two orgs measuring the same configuration are duplicates
    /// of the same grid point; merge keeps the first). Feature values are
    /// canonicalized (`-0.0` ≡ `0.0`, all NaNs ≡ `nan`) before formatting.
    pub fn config_key(&self) -> String {
        let feats: Vec<String> = self
            .job_features
            .iter()
            .map(|f| canonical_feature(*f))
            .collect();
        format!(
            "{}|{}|{}|{}",
            self.job.name(),
            self.machine,
            self.scaleout,
            feats.join(",")
        )
    }

    fn validate(&self) -> Result<(), String> {
        if self.scaleout == 0 {
            return Err("scaleout must be >= 1".into());
        }
        if !(self.runtime_s.is_finite() && self.runtime_s > 0.0) {
            return Err(format!("bad runtime {}", self.runtime_s));
        }
        if self.job_features.len() != self.job.feature_names().len() {
            return Err(format!(
                "{}: {} features, expected {}",
                self.job.name(),
                self.job_features.len(),
                self.job.feature_names().len()
            ));
        }
        if self.job_features.iter().any(|f| !f.is_finite()) {
            return Err("non-finite feature".into());
        }
        Ok(())
    }
}

/// A per-job shared repository of runtime records.
#[derive(Debug, Clone)]
pub struct RuntimeDataRepo {
    job: JobKind,
    records: Vec<RuntimeRecord>,
    /// Monotone generation counter: advances by the number of records a
    /// mutation actually added, and never moves otherwise. Consumers
    /// (the coordinator shards' model caches) key trained models on this
    /// value, so "the corpus did not change" is observable as "the
    /// generation did not change" — re-merging already-known data is a
    /// guaranteed no-op for retraining.
    generation: u64,
}

impl RuntimeDataRepo {
    /// Empty repository for a job.
    pub fn new(job: JobKind) -> Self {
        RuntimeDataRepo {
            job,
            records: Vec::new(),
            generation: 0,
        }
    }

    /// Build from records (e.g. a corpus slice); invalid or foreign-job
    /// records are rejected.
    pub fn from_records<I: IntoIterator<Item = RuntimeRecord>>(job: JobKind, records: I) -> Self {
        let mut repo = RuntimeDataRepo::new(job);
        for r in records {
            repo.contribute(r).expect("invalid record");
        }
        repo
    }

    pub fn job(&self) -> JobKind {
        self.job
    }

    pub fn records(&self) -> &[RuntimeRecord] {
        &self.records
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Current generation: advances by the number of records added. A
    /// repository whose generation is unchanged is guaranteed to hold
    /// exactly the same data, which is what the coordinator's model
    /// cache keys on.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Legacy alias for [`RuntimeDataRepo::generation`].
    pub fn version(&self) -> u64 {
        self.generation
    }

    /// Contribute one record (the "capture and save" step of Fig. 1).
    pub fn contribute(&mut self, r: RuntimeRecord) -> Result<(), String> {
        if r.job != self.job {
            return Err(format!(
                "record for {} contributed to {} repo",
                r.job.name(),
                self.job.name()
            ));
        }
        r.validate()?;
        self.records.push(r);
        self.generation += 1;
        Ok(())
    }

    /// Distinct contributing organizations.
    pub fn organizations(&self) -> BTreeSet<String> {
        self.records.iter().map(|r| r.org.clone()).collect()
    }

    /// Fork: an independent copy (DataHub/DVC-style).
    pub fn fork(&self) -> RuntimeDataRepo {
        self.clone()
    }

    /// Merge another repository of the same job into this one.
    /// Duplicate configurations (same [`RuntimeRecord::config_key`]) keep
    /// the existing record — idempotent re-merges don't grow the repo and
    /// don't advance the generation. Returns the number of records
    /// actually added (which is also how far the generation advanced).
    pub fn merge(&mut self, other: &RuntimeDataRepo) -> Result<usize, String> {
        if other.job != self.job {
            return Err("cannot merge repos of different jobs".into());
        }
        let mut existing: BTreeSet<String> =
            self.records.iter().map(|r| r.config_key()).collect();
        let mut added: usize = 0;
        for r in &other.records {
            if existing.insert(r.config_key()) {
                self.records.push(r.clone());
                added += 1;
            }
        }
        self.generation += added as u64;
        Ok(added)
    }

    /// CSV header for this job's schema.
    fn header(&self) -> Vec<String> {
        let mut h = vec![
            "job".to_string(),
            "org".to_string(),
            "machine".to_string(),
            "scaleout".to_string(),
        ];
        h.extend(self.job.feature_names().iter().map(|s| s.to_string()));
        h.push("runtime_s".to_string());
        h
    }

    /// Serialize to a CSV [`Table`] (the on-disk sharing format).
    pub fn to_table(&self) -> Table {
        let header = self.header();
        let mut t = Table {
            header,
            rows: Vec::new(),
        };
        for r in &self.records {
            let mut row = vec![
                r.job.name().to_string(),
                r.org.clone(),
                r.machine.clone(),
                r.scaleout.to_string(),
            ];
            row.extend(r.job_features.iter().map(|f| format!("{f}")));
            row.push(format!("{}", r.runtime_s));
            t.push(row);
        }
        t
    }

    /// Persist to CSV.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        self.to_table().save(path)
    }

    /// Load from CSV; rejects schema mismatches.
    pub fn load(job: JobKind, path: &Path) -> Result<RuntimeDataRepo, String> {
        let t = Table::load(path).map_err(|e| e.to_string())?;
        Self::from_table(job, &t)
    }

    /// Parse from a CSV table.
    pub fn from_table(job: JobKind, t: &Table) -> Result<RuntimeDataRepo, String> {
        let mut repo = RuntimeDataRepo::new(job);
        let expect = repo.header();
        if t.header != expect {
            return Err(format!(
                "schema mismatch: got {:?}, want {:?}",
                t.header, expect
            ));
        }
        let nf = job.feature_names().len();
        for row in &t.rows {
            let parse_f = |s: &str| -> Result<f64, String> {
                s.parse().map_err(|_| format!("bad number {s:?}"))
            };
            let rec = RuntimeRecord {
                job: JobKind::parse(&row[0]).ok_or_else(|| format!("bad job {:?}", row[0]))?,
                org: row[1].clone(),
                machine: row[2].clone(),
                scaleout: row[3].parse().map_err(|_| "bad scaleout".to_string())?,
                job_features: row[4..4 + nf]
                    .iter()
                    .map(|s| parse_f(s))
                    .collect::<Result<_, _>>()?,
                runtime_s: parse_f(&row[4 + nf])?,
            };
            repo.contribute(rec)?;
        }
        Ok(repo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(org: &str, machine: &str, scaleout: u32, gb: f64, runtime: f64) -> RuntimeRecord {
        RuntimeRecord {
            job: JobKind::Sort,
            org: org.into(),
            machine: machine.into(),
            scaleout,
            job_features: vec![gb],
            runtime_s: runtime,
        }
    }

    #[test]
    fn contribute_and_len() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        assert!(repo.is_empty());
        repo.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        assert_eq!(repo.len(), 1);
        assert_eq!(repo.version(), 1);
    }

    #[test]
    fn rejects_wrong_job() {
        let mut repo = RuntimeDataRepo::new(JobKind::Grep);
        let err = repo.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0));
        assert!(err.is_err());
    }

    #[test]
    fn rejects_invalid_records() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        assert!(repo.contribute(rec("a", "m", 0, 10.0, 100.0)).is_err());
        assert!(repo.contribute(rec("a", "m", 4, 10.0, -5.0)).is_err());
        assert!(repo.contribute(rec("a", "m", 4, f64::NAN, 5.0)).is_err());
        let wrong_arity = RuntimeRecord {
            job_features: vec![1.0, 2.0],
            ..rec("a", "m", 4, 10.0, 100.0)
        };
        assert!(repo.contribute(wrong_arity).is_err());
    }

    #[test]
    fn merge_dedups_by_config() {
        let mut a = RuntimeDataRepo::new(JobKind::Sort);
        a.contribute(rec("orgA", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        let mut b = a.fork();
        b.contribute(rec("orgB", "m5.xlarge", 8, 10.0, 60.0)).unwrap();
        // orgB also re-measured orgA's config — duplicate by key
        b.contribute(rec("orgB", "m5.xlarge", 4, 10.0, 102.0)).unwrap();
        let added = a.merge(&b).unwrap();
        assert_eq!(added, 1, "only the new configuration is merged");
        assert_eq!(a.len(), 2);
        // merging again adds nothing
        assert_eq!(a.merge(&b).unwrap(), 0);
    }

    #[test]
    fn config_key_normalizes_signed_zero_and_nan() {
        // -0.0 and 0.0 are the same grid point; they must share one key.
        let pos = rec("a", "m5.xlarge", 4, 0.0, 100.0);
        let neg = rec("b", "m5.xlarge", 4, -0.0, 102.0);
        assert_eq!(pos.config_key(), neg.config_key());
        // every NaN payload canonicalizes to the same token (config_key
        // must stay total even on records that validation would reject)
        let nan_a = rec("a", "m5.xlarge", 4, f64::NAN, 100.0);
        let nan_b = rec("a", "m5.xlarge", 4, -f64::NAN, 100.0);
        assert_eq!(nan_a.config_key(), nan_b.config_key());
        assert!(nan_a.config_key().contains("nan"));
    }

    #[test]
    fn merge_dedups_signed_zero_grid_points() {
        let mut a = RuntimeDataRepo::new(JobKind::Sort);
        a.contribute(rec("orgA", "m5.xlarge", 4, 0.0, 100.0)).unwrap();
        let mut b = RuntimeDataRepo::new(JobKind::Sort);
        b.contribute(rec("orgB", "m5.xlarge", 4, -0.0, 101.0)).unwrap();
        assert_eq!(a.merge(&b).unwrap(), 0, "-0.0 must dedup against 0.0");
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn generation_tracks_records_added() {
        let mut a = RuntimeDataRepo::new(JobKind::Sort);
        assert_eq!(a.generation(), 0);
        a.contribute(rec("a", "m5.xlarge", 4, 10.0, 100.0)).unwrap();
        assert_eq!(a.generation(), 1);
        let mut b = RuntimeDataRepo::new(JobKind::Sort);
        b.contribute(rec("b", "m5.xlarge", 6, 10.0, 90.0)).unwrap();
        b.contribute(rec("b", "m5.xlarge", 8, 10.0, 80.0)).unwrap();
        a.merge(&b).unwrap();
        assert_eq!(a.generation(), 3, "merge advances by records added");
        // idempotent re-merge: no data change, no generation change
        let before = a.generation();
        assert_eq!(a.merge(&b).unwrap(), 0);
        assert_eq!(a.generation(), before);
    }

    #[test]
    fn merge_rejects_cross_job() {
        let mut a = RuntimeDataRepo::new(JobKind::Sort);
        let b = RuntimeDataRepo::new(JobKind::Grep);
        assert!(a.merge(&b).is_err());
    }

    #[test]
    fn csv_round_trip() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("orgA", "m5.xlarge", 4, 12.5, 123.456)).unwrap();
        repo.contribute(rec("orgB", "c5.xlarge", 8, 20.0, 77.7)).unwrap();
        let t = repo.to_table();
        let back = RuntimeDataRepo::from_table(JobKind::Sort, &t).unwrap();
        assert_eq!(back.records(), repo.records());
    }

    #[test]
    fn csv_schema_mismatch_rejected() {
        let repo = RuntimeDataRepo::new(JobKind::Grep);
        let t = repo.to_table();
        assert!(RuntimeDataRepo::from_table(JobKind::Sort, &t).is_err());
    }

    #[test]
    fn organizations_collected() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("b", "m5.xlarge", 4, 10.0, 1.0)).unwrap();
        repo.contribute(rec("a", "m5.xlarge", 8, 10.0, 1.0)).unwrap();
        repo.contribute(rec("a", "m5.xlarge", 2, 10.0, 1.0)).unwrap();
        let orgs: Vec<String> = repo.organizations().into_iter().collect();
        assert_eq!(orgs, vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn file_round_trip() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("orgA", "m5.xlarge", 4, 12.5, 123.0)).unwrap();
        let dir = std::env::temp_dir().join("c3o_repo_test");
        let path = dir.join("sort.csv");
        repo.save(&path).unwrap();
        let back = RuntimeDataRepo::load(JobKind::Sort, &path).unwrap();
        assert_eq!(back.records(), repo.records());
        let _ = std::fs::remove_dir_all(dir);
    }
}
