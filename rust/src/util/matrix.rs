//! Dense row-major matrices over `f32` — the interchange type between the
//! L3 coordinator and the PJRT runtime (XLA literals are built from these
//! buffers) and the workhorse of the native model fallbacks.

/// Dense row-major `f32` matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct MatF32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl MatF32 {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatF32 {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// From a row-major buffer; panics if sizes mismatch.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        MatF32 { rows, cols, data }
    }

    /// From nested rows; panics on ragged input.
    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        MatF32 { rows: r, cols: c, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable row access.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Matrix product `self · other`.
    pub fn matmul(&self, other: &MatF32) -> MatF32 {
        assert_eq!(self.cols, other.rows, "inner dimensions must agree");
        let mut out = MatF32::zeros(self.rows, other.cols);
        // ikj loop order: streams `other` rows, keeps the accumulator row hot.
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Transpose.
    pub fn transpose(&self) -> MatF32 {
        let mut out = MatF32::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.set(c, r, self.at(r, c));
            }
        }
        out
    }

    /// Column-wise mean and standard deviation (for feature scaling).
    pub fn col_stats(&self) -> (Vec<f32>, Vec<f32>) {
        let mut mean = vec![0.0f32; self.cols];
        let mut sd = vec![0.0f32; self.cols];
        if self.rows == 0 {
            return (mean, vec![1.0; self.cols]);
        }
        for r in 0..self.rows {
            for c in 0..self.cols {
                mean[c] += self.at(r, c);
            }
        }
        for m in &mut mean {
            *m /= self.rows as f32;
        }
        for r in 0..self.rows {
            for c in 0..self.cols {
                let d = self.at(r, c) - mean[c];
                sd[c] += d * d;
            }
        }
        for s in &mut sd {
            *s = (*s / self.rows as f32).sqrt();
            if *s < 1e-9 {
                *s = 1.0; // constant column: don't blow up scaling
            }
        }
        (mean, sd)
    }

    /// Standardize columns in place given mean/sd (z-scoring).
    pub fn standardize(&mut self, mean: &[f32], sd: &[f32]) {
        assert_eq!(mean.len(), self.cols);
        assert_eq!(sd.len(), self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                let v = (self.at(r, c) - mean[c]) / sd[c];
                self.set(r, c, v);
            }
        }
    }

    /// Vertically stack two matrices with equal column counts.
    pub fn vstack(&self, other: &MatF32) -> MatF32 {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        MatF32 {
            rows: self.rows + other.rows,
            cols: self.cols,
            data,
        }
    }

    /// Select a subset of rows by index.
    pub fn select_rows(&self, idx: &[usize]) -> MatF32 {
        let mut out = MatF32::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(r));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = MatF32::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let i = MatF32::from_rows(&[vec![1.0, 0.0], vec![0.0, 1.0]]);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_known() {
        let a = MatF32::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = MatF32::from_rows(&[vec![1.0, 1.0], vec![1.0, 1.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_round_trip() {
        let a = MatF32::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().at(2, 1), 6.0);
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut a = MatF32::from_rows(&[vec![1.0], vec![3.0], vec![5.0]]);
        let (m, s) = a.col_stats();
        a.standardize(&m, &s);
        let mean: f32 = a.data.iter().sum::<f32>() / 3.0;
        assert!(mean.abs() < 1e-6);
    }

    #[test]
    fn constant_column_sd_is_one() {
        let a = MatF32::from_rows(&[vec![7.0], vec![7.0]]);
        let (_, s) = a.col_stats();
        assert_eq!(s[0], 1.0);
    }

    #[test]
    fn vstack_and_select() {
        let a = MatF32::from_rows(&[vec![1.0, 2.0]]);
        let b = MatF32::from_rows(&[vec![3.0, 4.0], vec![5.0, 6.0]]);
        let c = a.vstack(&b);
        assert_eq!(c.rows, 3);
        let sel = c.select_rows(&[2, 0]);
        assert_eq!(sel.data, vec![5.0, 6.0, 1.0, 2.0]);
    }
}
