//! Concurrency stress for the `c3o::obs` trace collector's bounded
//! MPMC ring. These tests are deliberately thread-heavy so the nightly
//! ThreadSanitizer job exercises the lock-free slot handoff: producers
//! `force_push` (overwriting the oldest entry when full) while
//! consumers `pop` concurrently, and every value that comes out must be
//! one that went in — no torn reads, no duplicates, no invented data.

use c3o::obs::Ring;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Tag a value with its producer so consumers can check per-producer
/// order: producer `p` pushes `p * STRIDE + i` for increasing `i`.
const STRIDE: u64 = 1 << 32;

#[test]
fn concurrent_force_push_and_pop_yield_only_valid_values() {
    const PRODUCERS: usize = 4;
    const CONSUMERS: usize = 2;
    const PER_PRODUCER: u64 = 20_000;

    let ring: Arc<Ring<u64>> = Arc::new(Ring::new(64));
    let done = Arc::new(AtomicBool::new(false));

    let consumed: Vec<Vec<u64>> = std::thread::scope(|scope| {
        let producers: Vec<_> = (0..PRODUCERS as u64)
            .map(|p| {
                let ring = Arc::clone(&ring);
                scope.spawn(move || {
                    for i in 0..PER_PRODUCER {
                        ring.force_push(p * STRIDE + i);
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..CONSUMERS)
            .map(|_| {
                let ring = Arc::clone(&ring);
                let done = Arc::clone(&done);
                scope.spawn(move || {
                    let mut seen = Vec::new();
                    loop {
                        match ring.pop() {
                            Some(v) => seen.push(v),
                            None if done.load(Ordering::Acquire) => {
                                // a push may have landed between the
                                // last pop and the flag read — drain it
                                while let Some(v) = ring.pop() {
                                    seen.push(v);
                                }
                                return seen;
                            }
                            None => std::thread::yield_now(),
                        }
                    }
                })
            })
            .collect();
        for h in producers {
            h.join().expect("producer panicked");
        }
        done.store(true, Ordering::Release);
        consumers
            .into_iter()
            .map(|h| h.join().expect("consumer panicked"))
            .collect()
    });

    // every push is accounted for: popped by a consumer or overwritten
    let total_popped: usize = consumed.iter().map(Vec::len).sum();
    let lost = ring.lost() as usize;
    assert!(ring.pop().is_none(), "consumers drained the ring");
    assert_eq!(
        total_popped + lost,
        PRODUCERS * PER_PRODUCER as usize,
        "every push is either popped or overwritten (lost), never both"
    );

    // every value is one a producer actually pushed, and within each
    // consumer the values from any single producer arrive in push order
    // (overwrites drop the oldest; they never reorder survivors)
    for seen in &consumed {
        let mut last_per_producer: HashMap<u64, u64> = HashMap::new();
        for &v in seen {
            let p = v / STRIDE;
            let i = v % STRIDE;
            assert!(p < PRODUCERS as u64, "value from a nonexistent producer");
            assert!(i < PER_PRODUCER, "value index out of range");
            if let Some(&prev) = last_per_producer.get(&p) {
                assert!(
                    i > prev,
                    "producer {p}: value {i} arrived after {prev} out of order"
                );
            }
            last_per_producer.insert(p, i);
        }
    }
}

#[test]
fn force_push_overwrites_oldest_under_contention() {
    const CAP: usize = 8;
    let ring: Ring<u64> = Ring::new(CAP);
    // overfill 4x with no consumer: exactly the newest CAP survive
    for v in 0..(4 * CAP as u64) {
        ring.force_push(v);
    }
    let mut survivors = Vec::new();
    while let Some(v) = ring.pop() {
        survivors.push(v);
    }
    assert_eq!(survivors.len(), CAP);
    assert_eq!(ring.lost(), 3 * CAP as u64);
    let expect: Vec<u64> = (3 * CAP as u64..4 * CAP as u64).collect();
    assert_eq!(survivors, expect, "the oldest entries are the ones dropped");
}
