//! Figure/table regenerators: one function per table and figure of the
//! paper's evaluation (§IV), each returning the underlying data (a CSV
//! [`Table`]) plus machine-checkable **claims** — the qualitative
//! statements the paper makes about that figure. The bench targets print
//! the tables and assert the claims; EXPERIMENTS.md records the outcome.
//!
//! | Function  | Paper artefact | Claim checked |
//! |-----------|----------------|---------------|
//! | [`table1`] | Table I       | 930 experiments with the exact per-job counts |
//! | [`fig3`]   | Fig. 3        | machine-type cost-efficiency ranking is scale-out-stable, except memory bottlenecks (SGD/K-Means at low scale-out) |
//! | [`fig4`]   | Fig. 4        | key dataset characteristics influence runtime linearly (R² of linear fit) |
//! | [`fig5`]   | Fig. 5        | algorithm parameters influence runtime non-linearly |
//! | [`fig6`]   | Fig. 6        | SGD/K-Means speedup(2→4) > 2 (memory bottleneck); PageRank scales poorly |
//! | [`fig7`]   | Fig. 7        | Grep scale-out *shape* invariant to dataset size, variant in keyword ratio |

use crate::cloud::Cloud;
use crate::sim::{SimConfig, Simulator};
use crate::util::csv::Table;
use crate::util::rng::Pcg32;
use crate::util::stats::{self, median};
use crate::workloads::{grid::SCALEOUTS, JobKind, JobSpec};

/// One reproduced artefact: data + verified claims.
#[derive(Debug, Clone)]
pub struct FigureData {
    pub name: String,
    pub table: Table,
    /// (claim text, holds?) — every claim must hold for the reproduction
    /// to count.
    pub claims: Vec<(String, bool)>,
}

impl FigureData {
    pub fn all_claims_hold(&self) -> bool {
        self.claims.iter().all(|(_, ok)| *ok)
    }

    /// Human-readable report: claims then the data table.
    pub fn render(&self) -> String {
        let mut out = format!("=== {} ===\n", self.name);
        for (claim, ok) in &self.claims {
            out.push_str(&format!("  [{}] {}\n", if *ok { "PASS" } else { "FAIL" }, claim));
        }
        out.push('\n');
        out.push_str(&render_table(&self.table));
        out
    }
}

/// Fixed-width ASCII rendering of a CSV table.
pub fn render_table(t: &Table) -> String {
    let mut widths: Vec<usize> = t.header.iter().map(|h| h.len()).collect();
    for row in &t.rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:>w$}"))
            .collect::<Vec<_>>()
            .join("  ")
    };
    out.push_str(&fmt_row(&t.header, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    out.push('\n');
    for row in &t.rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

/// Median-of-reps runtime of a spec on a configuration (the paper's
/// measurement protocol).
fn measure(
    cloud: &Cloud,
    sim: &Simulator,
    spec: &JobSpec,
    machine: &str,
    n: u32,
    reps: u32,
    seed: u64,
) -> f64 {
    let mt = cloud.machine(machine).expect("machine in catalog");
    let stages = spec.stages();
    let runs: Vec<f64> = (0..reps)
        .map(|rep| {
            let mut rng = Pcg32::new_stream(seed ^ (rep as u64) << 17, (n as u64) << 8 | rep as u64 | 1);
            sim.run(mt, n, &stages, &mut rng).runtime_s
        })
        .collect();
    median(&runs)
}

fn f(v: f64) -> String {
    format!("{v:.2}")
}

// ---------------------------------------------------------------------------
// Table I
// ---------------------------------------------------------------------------

/// Regenerate Table I: execute the full 930-experiment grid and summarize
/// per-job counts and runtime ranges.
pub fn table1(cloud: &Cloud, seed: u64) -> FigureData {
    let grid = crate::workloads::ExperimentGrid::paper_table1();
    let corpus = grid.execute(cloud, seed);
    let mut table = Table::new(&["job", "experiments", "median_runtime_s", "min_s", "max_s"]);
    let mut claims = Vec::new();
    let want = [
        (JobKind::Sort, 126usize),
        (JobKind::Grep, 162),
        (JobKind::Sgd, 180),
        (JobKind::KMeans, 180),
        (JobKind::PageRank, 282),
    ];
    for (kind, want_n) in want {
        let runtimes: Vec<f64> = corpus
            .records_for(kind)
            .iter()
            .map(|r| r.runtime_s)
            .collect();
        table.push(vec![
            kind.name().to_string(),
            runtimes.len().to_string(),
            f(median(&runtimes)),
            f(runtimes.iter().fold(f64::INFINITY, |a, &b| a.min(b))),
            f(runtimes.iter().fold(0.0f64, |a, &b| a.max(b))),
        ]);
        claims.push((
            format!("{}: exactly {} unique experiments", kind.name(), want_n),
            runtimes.len() == want_n,
        ));
    }
    claims.push((
        "930 unique experiments in total".to_string(),
        corpus.len() == 930,
    ));
    FigureData {
        name: "Table I: overview of benchmark jobs".to_string(),
        table,
        claims,
    }
}

// ---------------------------------------------------------------------------
// Fig. 3 — machine types and cost-efficiency at different scale-outs
// ---------------------------------------------------------------------------

/// Jobs' specs used for the figure sweeps (mid-grid settings).
pub fn representative_specs() -> Vec<JobSpec> {
    vec![
        JobSpec::sort(15.0),
        JobSpec::grep(15.0, 0.1),
        JobSpec::sgd(30.0, 100),
        JobSpec::kmeans(20.0, 5, 0.001),
        JobSpec::pagerank(330.0, 0.001),
    ]
}

/// Fig. 3: for each job × machine type × scale-out, the (runtime, cost)
/// frontier; claims: ranking stability for CPU-bound jobs + the memory
/// exception for SGD/K-Means.
pub fn fig3(cloud: &Cloud, seed: u64) -> FigureData {
    let sim = Simulator::new(SimConfig::default());
    let machines = ["c5.xlarge", "m5.xlarge", "r5.xlarge"];
    let mut table = Table::new(&["job", "machine", "scaleout", "runtime_s", "cost_usd"]);
    // job -> machine -> scaleout -> cost
    let mut costs: std::collections::HashMap<(String, String), Vec<(u32, f64)>> =
        std::collections::HashMap::new();
    for spec in representative_specs() {
        for machine in machines {
            for &n in SCALEOUTS.iter().rev() {
                let t = measure(cloud, &sim, &spec, machine, n, 5, seed);
                let cost = cloud.cost_usd(machine, n, t);
                table.push(vec![
                    spec.kind().name().to_string(),
                    machine.to_string(),
                    n.to_string(),
                    f(t),
                    format!("{cost:.4}"),
                ]);
                costs
                    .entry((spec.kind().name().to_string(), machine.to_string()))
                    .or_default()
                    .push((n, cost));
            }
        }
    }

    // ranking of machine types at each scale-out for a job
    let ranking = |job: &str, n: u32| -> Vec<String> {
        let mut v: Vec<(String, f64)> = machines
            .iter()
            .map(|m| {
                let c = costs[&(job.to_string(), m.to_string())]
                    .iter()
                    .find(|(nn, _)| *nn == n)
                    .unwrap()
                    .1;
                (m.to_string(), c)
            })
            .collect();
        v.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        v.into_iter().map(|(m, _)| m).collect()
    };

    let mut claims = Vec::new();
    // CPU/IO-bound jobs: ranking identical across scale-outs
    for job in ["sort", "grep", "pagerank"] {
        let base = ranking(job, 12);
        let stable = SCALEOUTS.iter().all(|&n| ranking(job, n) == base);
        claims.push((
            format!("{job}: cost-efficiency ranking of machine types is scale-out-stable"),
            stable,
        ));
    }
    // memory exception: for SGD, r5 ranks better at n=2 than at n=12
    for job in ["sgd", "kmeans"] {
        let rank_of = |n: u32, m: &str| ranking(job, n).iter().position(|x| x == m).unwrap();
        let exception = rank_of(2, "r5.xlarge") < rank_of(12, "r5.xlarge")
            || rank_of(2, "c5.xlarge") > rank_of(12, "c5.xlarge");
        claims.push((
            format!("{job}: memory bottleneck shifts the low-scale-out ranking toward RAM-rich types"),
            exception,
        ));
    }
    // "lower scale-outs typically cost less" for the scalable jobs
    // (absent memory bottlenecks)
    let sort_m5 = &costs[&("sort".to_string(), "m5.xlarge".to_string())];
    let c2 = sort_m5.iter().find(|(n, _)| *n == 2).unwrap().1;
    let c12 = sort_m5.iter().find(|(n, _)| *n == 12).unwrap().1;
    claims.push((
        "sort: scale-out 2 costs less than scale-out 12 (no bottleneck)".to_string(),
        c2 < c12,
    ));
    FigureData {
        name: "Fig. 3: machine types and cost-efficiency at different scale-outs".to_string(),
        table,
        claims,
    }
}

// ---------------------------------------------------------------------------
// Fig. 4 — influence of key data characteristics on runtime (linear)
// ---------------------------------------------------------------------------

/// Fig. 4: sweep one data characteristic per job with everything else
/// fixed; claim: a linear fit explains ≥ 95% of the variance.
pub fn fig4(cloud: &Cloud, seed: u64) -> FigureData {
    let sim = Simulator::new(SimConfig::default());
    let machine = "m5.xlarge";
    let n = 6;
    let mut table = Table::new(&["job", "characteristic", "value", "runtime_s"]);
    let mut claims = Vec::new();

    let sweeps: Vec<(&str, &str, Vec<f64>, Box<dyn Fn(f64) -> JobSpec>)> = vec![
        (
            "sort",
            "data_gb",
            vec![10.0, 12.0, 14.0, 16.0, 18.0, 20.0],
            Box::new(|gb| JobSpec::sort(gb)),
        ),
        (
            "grep",
            "data_gb",
            vec![10.0, 12.0, 14.0, 16.0, 18.0, 20.0],
            Box::new(|gb| JobSpec::grep(gb, 0.1)),
        ),
        (
            "grep",
            "keyword_ratio",
            vec![0.01, 0.05, 0.1, 0.15, 0.2, 0.3],
            Box::new(|r| JobSpec::grep(15.0, r)),
        ),
        (
            "sgd",
            "data_gb",
            vec![10.0, 14.0, 18.0, 22.0, 26.0, 30.0],
            Box::new(|gb| JobSpec::sgd(gb, 50)),
        ),
        (
            "kmeans",
            "data_gb",
            vec![10.0, 12.0, 14.0, 16.0, 18.0, 20.0],
            Box::new(|gb| JobSpec::kmeans(gb, 5, 0.001)),
        ),
        (
            "pagerank",
            "graph_mb",
            vec![130.0, 190.0, 250.0, 310.0, 370.0, 440.0],
            Box::new(|mb| JobSpec::pagerank(mb, 0.001)),
        ),
    ];

    for (job, feat, values, make) in sweeps {
        let mut ts = Vec::new();
        for &v in &values {
            let t = measure(cloud, &sim, &make(v), machine, n, 5, seed);
            table.push(vec![
                job.to_string(),
                feat.to_string(),
                format!("{v}"),
                f(t),
            ]);
            ts.push(t);
        }
        let (_, slope, r2) = stats::linfit(&values, &ts);
        claims.push((
            format!("{job}: runtime linear in {feat} (R²={r2:.3} ≥ 0.95, slope>0)"),
            r2 >= 0.95 && slope > 0.0,
        ));
    }
    FigureData {
        name: "Fig. 4: influence of key data characteristics on the runtime".to_string(),
        table,
        claims,
    }
}

// ---------------------------------------------------------------------------
// Fig. 5 — influence of algorithm parameters on runtime (non-linear)
// ---------------------------------------------------------------------------

/// Fig. 5: sweep one parameter per iterative job; claim: the relationship
/// is non-linear (a linear fit leaves ≥ 3% unexplained variance, and the
/// curve's curvature is significant).
pub fn fig5(cloud: &Cloud, seed: u64) -> FigureData {
    let sim = Simulator::new(SimConfig::default());
    let machine = "m5.xlarge";
    let n = 6;
    let mut table = Table::new(&["job", "parameter", "value", "runtime_s"]);
    let mut claims = Vec::new();

    let sweeps: Vec<(&str, &str, Vec<f64>, Box<dyn Fn(f64) -> JobSpec>)> = vec![
        (
            "sgd",
            "max_iterations",
            vec![1.0, 20.0, 40.0, 60.0, 80.0, 100.0],
            Box::new(|i| JobSpec::sgd(20.0, i as u32)),
        ),
        (
            "kmeans",
            "num_clusters",
            vec![3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0],
            Box::new(|k| JobSpec::kmeans(15.0, k as u32, 0.001)),
        ),
        (
            "pagerank",
            "convergence",
            vec![0.01, 0.005, 0.001, 0.0005, 0.0001],
            Box::new(|c| JobSpec::pagerank(330.0, c)),
        ),
    ];

    for (job, param, values, make) in sweeps {
        let mut ts = Vec::new();
        for &v in &values {
            let t = measure(cloud, &sim, &make(v), machine, n, 5, seed);
            table.push(vec![
                job.to_string(),
                param.to_string(),
                format!("{v}"),
                f(t),
            ]);
            ts.push(t);
        }
        let (_, _, r2) = stats::linfit(&values, &ts);
        claims.push((
            format!("{job}: runtime non-linear in {param} (linear fit R²={r2:.3} < 0.97)"),
            r2 < 0.97,
        ));
    }
    FigureData {
        name: "Fig. 5: influence of different input parameters on the runtime".to_string(),
        table,
        claims,
    }
}

// ---------------------------------------------------------------------------
// Fig. 6 — scale-out behavior
// ---------------------------------------------------------------------------

/// Fig. 6: runtime vs scale-out per job; claims: SGD/K-Means doubling
/// 2→4 gives speedup > 2 (memory bottleneck) and PageRank benefits
/// relatively little from scaling out.
pub fn fig6(cloud: &Cloud, seed: u64) -> FigureData {
    let sim = Simulator::new(SimConfig::default());
    let machine = "m5.xlarge";
    let mut table = Table::new(&["job", "scaleout", "runtime_s"]);
    let mut curves: std::collections::HashMap<String, Vec<f64>> = std::collections::HashMap::new();
    for spec in representative_specs() {
        for &n in &SCALEOUTS {
            let t = measure(cloud, &sim, &spec, machine, n, 5, seed);
            table.push(vec![
                spec.kind().name().to_string(),
                n.to_string(),
                f(t),
            ]);
            curves
                .entry(spec.kind().name().to_string())
                .or_default()
                .push(t);
        }
    }
    let speedup_2_4 = |job: &str| curves[job][0] / curves[job][1];
    let speedup_2_12 = |job: &str| curves[job][0] / curves[job][5];
    let mut claims = vec![
        (
            format!(
                "sgd: doubling 2→4 nodes gives speedup {:.2} > 2 (memory bottleneck)",
                speedup_2_4("sgd")
            ),
            speedup_2_4("sgd") > 2.0,
        ),
        (
            format!(
                "kmeans: doubling 2→4 nodes gives speedup {:.2} > 2 (memory bottleneck)",
                speedup_2_4("kmeans")
            ),
            speedup_2_4("kmeans") > 2.0,
        ),
        (
            format!(
                "pagerank: benefits relatively little from scale-out (2→12 speedup {:.2} < 2)",
                speedup_2_12("pagerank")
            ),
            speedup_2_12("pagerank") < 2.0,
        ),
    ];
    // the non-bottlenecked jobs show ordinary sublinear scaling
    for job in ["sort", "grep"] {
        let s = speedup_2_4(job);
        claims.push((
            format!("{job}: doubling 2→4 nodes gives ordinary speedup ({s:.2} ≤ 2)"),
            s <= 2.0,
        ));
    }
    FigureData {
        name: "Fig. 6: scale-out behavior".to_string(),
        table,
        claims,
    }
}

// ---------------------------------------------------------------------------
// Fig. 7 — scale-out behavior vs other factors (Grep)
// ---------------------------------------------------------------------------

/// Fig. 7: Grep scale-out curves across dataset sizes (shape-invariant)
/// and keyword ratios (shape-variant).
pub fn fig7(cloud: &Cloud, seed: u64) -> FigureData {
    let sim = Simulator::new(SimConfig::default());
    let machine = "m5.xlarge";
    let mut table = Table::new(&["variant", "scaleout", "runtime_s"]);
    let curve = |label: &str, spec: &JobSpec, table: &mut Table| -> Vec<f64> {
        SCALEOUTS
            .iter()
            .map(|&n| {
                let t = measure(cloud, &sim, spec, machine, n, 5, seed);
                table.push(vec![label.to_string(), n.to_string(), f(t)]);
                t
            })
            .collect()
    };
    let size10 = curve("size=10GB,ratio=0.1", &JobSpec::grep(10.0, 0.1), &mut table);
    let size20 = curve("size=20GB,ratio=0.1", &JobSpec::grep(20.0, 0.1), &mut table);
    let ratio_lo = curve("size=15GB,ratio=0.01", &JobSpec::grep(15.0, 0.01), &mut table);
    let ratio_hi = curve("size=15GB,ratio=0.3", &JobSpec::grep(15.0, 0.3), &mut table);

    let div_size = stats::curve_shape_divergence(&size10, &size20);
    let div_ratio = stats::curve_shape_divergence(&ratio_lo, &ratio_hi);
    let claims = vec![
        (
            format!(
                "dataset size does not significantly change the scale-out shape (divergence {div_size:.3})"
            ),
            div_size < 0.10,
        ),
        (
            format!("keyword ratio does change the scale-out shape (divergence {div_ratio:.3})"),
            div_ratio > 2.0 * div_size && div_ratio > 0.05,
        ),
    ];
    FigureData {
        name: "Fig. 7: scale-out behavior vs other factors (Grep)".to_string(),
        table,
        claims,
    }
}

/// All figure regenerators, for the CLI.
pub fn all(cloud: &Cloud, seed: u64) -> Vec<FigureData> {
    vec![
        table1(cloud, seed),
        fig3(cloud, seed),
        fig4(cloud, seed),
        fig5(cloud, seed),
        fig6(cloud, seed),
        fig7(cloud, seed),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check(fig: FigureData) {
        for (claim, ok) in &fig.claims {
            assert!(ok, "{}: claim failed: {claim}\n{}", fig.name, fig.render());
        }
        assert!(!fig.table.rows.is_empty());
    }

    #[test]
    fn fig3_claims_hold() {
        check(fig3(&Cloud::aws_like(), 42));
    }

    #[test]
    fn fig4_claims_hold() {
        check(fig4(&Cloud::aws_like(), 42));
    }

    #[test]
    fn fig5_claims_hold() {
        check(fig5(&Cloud::aws_like(), 42));
    }

    #[test]
    fn fig6_claims_hold() {
        check(fig6(&Cloud::aws_like(), 42));
    }

    #[test]
    fn fig7_claims_hold() {
        check(fig7(&Cloud::aws_like(), 42));
    }

    #[test]
    fn table1_claims_hold() {
        check(table1(&Cloud::aws_like(), 42));
    }

    #[test]
    fn render_table_is_aligned() {
        let mut t = Table::new(&["a", "long_header"]);
        t.push(vec!["1".into(), "2".into()]);
        let s = render_table(&t);
        assert!(s.contains("long_header"));
        assert!(s.lines().count() == 3);
    }
}
