//! Billing policies.
//!
//! EC2 Linux on-demand billing is per-second with a 60-second minimum per
//! instance; EMR adds a small per-instance surcharge which we fold into the
//! hourly price. The policy matters for the configurator: short jobs on
//! huge clusters pay the minimum, which shifts the cheapest-configuration
//! frontier exactly as Fig. 3's left-most points (largest scale-outs) show.

/// How cluster time is turned into dollars.
#[derive(Debug, Clone, PartialEq)]
pub struct BillingPolicy {
    /// Billing granularity in seconds (1 = per-second).
    pub granularity_s: u64,
    /// Minimum billed seconds per instance.
    pub minimum_s: u64,
}

impl BillingPolicy {
    /// Per-second billing with a minimum charge (EC2 Linux: 60 s minimum).
    pub fn per_second_with_minimum(minimum_s: u64) -> Self {
        BillingPolicy {
            granularity_s: 1,
            minimum_s,
        }
    }

    /// Whole-hour billing (pre-2017 EC2; used in billing ablations).
    pub fn hourly() -> Self {
        BillingPolicy {
            granularity_s: 3600,
            minimum_s: 3600,
        }
    }

    /// Billed seconds for a wall-clock duration.
    pub fn billed_seconds(&self, seconds: f64) -> f64 {
        let s = seconds.max(self.minimum_s as f64);
        let g = self.granularity_s as f64;
        (s / g).ceil() * g
    }

    /// Cost in USD for `count` instances at `price_usd_hour` held for
    /// `seconds` of wall-clock time.
    pub fn cost_usd(&self, price_usd_hour: f64, count: u32, seconds: f64) -> f64 {
        self.billed_seconds(seconds) / 3600.0 * price_usd_hour * count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimum_applies() {
        let p = BillingPolicy::per_second_with_minimum(60);
        assert_eq!(p.billed_seconds(10.0), 60.0);
        assert_eq!(p.billed_seconds(61.5), 62.0);
    }

    #[test]
    fn hourly_rounds_up() {
        let p = BillingPolicy::hourly();
        assert_eq!(p.billed_seconds(1.0), 3600.0);
        assert_eq!(p.billed_seconds(3601.0), 7200.0);
    }

    #[test]
    fn cost_formula() {
        let p = BillingPolicy::per_second_with_minimum(60);
        // 10 nodes × $0.36/h × 1800 s = $1.80
        let c = p.cost_usd(0.36, 10, 1800.0);
        assert!((c - 1.8).abs() < 1e-9);
    }

    #[test]
    fn short_jobs_pay_minimum() {
        let p = BillingPolicy::per_second_with_minimum(60);
        let short = p.cost_usd(1.0, 100, 5.0);
        let full = p.cost_usd(1.0, 100, 60.0);
        assert_eq!(short, full);
    }
}
