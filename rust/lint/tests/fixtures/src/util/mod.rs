//! Fixture: `util` is `no-anyhow-public`-exempt and boundary-zoned, so
//! neither the anyhow signature nor the index fires.

pub fn helper() -> anyhow::Result<u32> {
    let xs = [1u32, 2];
    Ok(xs[0])
}
