//! Runtime-target sweep: how the configurator trades cost against the
//! user's deadline (paper Fig. 1's "runtime target" input).
//!
//! For one Sort job, sweep the target from very tight to very loose and
//! print the chosen configuration, predicted runtime, and expected cost
//! at each point — the cost/deadline frontier a C3O user navigates.
//!
//! The sweep is pure **read traffic**: one `Share` write trains the
//! model, then every point is a `Recommend` query through the
//! deployment-agnostic [`Client`] protocol — nothing is provisioned or
//! run, and the shared repository's generation never moves.
//!
//! Run with: `make artifacts && cargo run --release --example runtime_target_sweep`

use c3o::prelude::*;

fn main() -> anyhow::Result<()> {
    let artifacts = c3o::runtime::Runtime::default_dir();
    if !c3o::runtime::Runtime::artifacts_available(&artifacts) {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let cloud = Cloud::aws_like();

    println!("building the Sort shared corpus...");
    let grid = ExperimentGrid {
        experiments: ExperimentGrid::paper_table1()
            .experiments
            .into_iter()
            .filter(|e| e.spec.kind() == JobKind::Sort)
            .collect(),
        repetitions: 5,
    };
    let repo = grid.execute(&cloud, 42).repo_for(JobKind::Sort);

    let mut coordinator = Coordinator::new(cloud, &artifacts, 1)?;
    let client: &mut dyn Client = &mut coordinator;
    client.share(repo)?; // the write that trains the model

    let info = client.snapshot_info(JobKind::Sort)?;
    println!(
        "model: {:?} trained on {} shared records (generation {})\n",
        info.model, info.records, info.generation
    );

    println!(
        "{:>9} {:>12} {:>4} {:>11} {:>10} {:>6}",
        "target_s", "machine", "n", "predicted_s", "cost_usd", "met"
    );
    let spec_gb = 17.0;
    for target in [60.0, 120.0, 180.0, 240.0, 300.0, 420.0, 600.0, 900.0, 1800.0] {
        let request = JobRequest::sort(spec_gb).with_target_seconds(target);
        let rec = client.recommend(request)?;
        println!(
            "{:>9.0} {:>12} {:>4} {:>11.1} {:>10.3} {:>6}",
            target,
            rec.choice.machine_type,
            rec.choice.node_count,
            rec.choice.predicted_runtime_s,
            rec.choice.expected_cost_usd,
            rec.choice.meets_target
        );
    }

    let after = client.snapshot_info(JobKind::Sort)?;
    assert_eq!(
        info.generation, after.generation,
        "recommendations are reads: the repository never moved"
    );

    println!(
        "\nNote how looser targets let the configurator drop to smaller/cheaper\n\
         clusters, while very tight targets force the fastest configuration even\n\
         when the deadline is unattainable (met = false). The whole sweep was\n\
         served read-only from one immutable model snapshot."
    );
    Ok(())
}
