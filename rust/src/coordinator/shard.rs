//! Per-[`JobKind`] shard: the unit of state ownership in the
//! coordination stack.
//!
//! A shard owns everything one job kind needs — its shared runtime-data
//! repository, its generation-cached trained model, and its RNG stream —
//! and nothing else, so distinct kinds never contend. Both deployment
//! shapes drive the same shard code: the sequential [`super::Coordinator`]
//! holds plain shards, the multi-worker [`super::service`] wraps each in
//! a mutex and lets any worker thread serve any shard with its own model
//! engine.
//!
//! **Generation-cached models:** a trained model is tagged with the repo
//! [`generation`](crate::repo::RuntimeDataRepo::generation) it was
//! trained at. The shard retrains only when the generation advanced past
//! the retrain threshold — merging already-known data does not move the
//! generation, so redundant sharing can never trigger redundant training
//! (observable through [`Metrics::retrains`] / [`Metrics::cache_hits`]).

use crate::baselines::{ConfigSearch, NaiveMax};
use crate::cloud::Cloud;
use crate::configurator::{Configurator, JobRequest};
use crate::coordinator::{JobOutcome, Metrics, Organization};
use crate::models::oracle::SimOracle;
use crate::models::selection::{select_and_train, SelectionReport};
use crate::models::{EngineBound, ModelKind, ModelTrainer, TrainedModel};
use crate::repo::sampling::sampled_repo;
use crate::repo::{RuntimeDataRepo, RuntimeRecord};
use crate::util::rng::Pcg32;
use crate::workloads::JobKind;
use anyhow::{Context, Result};
use std::collections::BTreeSet;

/// Retrain/cold-start policy knobs shared by every shard of a deployment.
#[derive(Debug, Clone)]
pub struct ShardPolicy {
    /// Retrain when the repo generation advanced this far since the last
    /// training.
    pub retrain_every: u64,
    /// Minimum records before the model path activates (cold-start
    /// threshold).
    pub min_records: usize,
    /// CV folds for dynamic selection.
    pub cv_folds: usize,
}

impl Default for ShardPolicy {
    fn default() -> Self {
        ShardPolicy {
            retrain_every: 12,
            min_records: 12,
            cv_folds: 4,
        }
    }
}

/// A trained model tagged with the repo generation it was trained at.
#[derive(Debug)]
pub struct CachedModel {
    pub trained_at_gen: u64,
    pub model: TrainedModel,
    pub report: SelectionReport,
}

/// Per-job-kind state: repository + generation-cached model + RNG stream.
pub struct JobShard {
    job: JobKind,
    repo: RuntimeDataRepo,
    model: Option<CachedModel>,
    rng: Pcg32,
}

impl JobShard {
    /// Fresh shard for one job kind.
    pub fn new(job: JobKind, seed: u64) -> JobShard {
        JobShard {
            job,
            repo: RuntimeDataRepo::new(job),
            model: None,
            rng: Pcg32::new(seed),
        }
    }

    pub fn job(&self) -> JobKind {
        self.job
    }

    /// The shard's shared repository.
    pub fn repo(&self) -> &RuntimeDataRepo {
        &self.repo
    }

    /// Current repo generation (the model-cache key).
    pub fn generation(&self) -> u64 {
        self.repo.generation()
    }

    /// The generation the cached model was trained at, if any.
    pub fn trained_at_generation(&self) -> Option<u64> {
        self.model.as_ref().map(|m| m.trained_at_gen)
    }

    /// Latest selection report, if a model is cached.
    pub fn selection_report(&self) -> Option<&SelectionReport> {
        self.model.as_ref().map(|m| &m.report)
    }

    /// Merge shared runtime data into the shard's repository. Returns
    /// records actually added (== generation advance).
    pub fn share(&mut self, other: &RuntimeDataRepo) -> Result<usize> {
        self.repo.merge(other).map_err(anyhow::Error::msg)
    }

    /// Ensure a generation-fresh model: retrain via dynamic selection
    /// only when the repo generation advanced by `retrain_every` since
    /// the cached model was trained. Returns the active model kind, or
    /// `None` below the cold-start threshold.
    pub fn ensure_model(
        &mut self,
        engine: &mut dyn ModelTrainer,
        cloud: &Cloud,
        policy: &ShardPolicy,
        metrics: &mut Metrics,
    ) -> Result<Option<ModelKind>> {
        if self.repo.len() < policy.min_records {
            return Ok(None);
        }
        let gen = self.repo.generation();
        let stale = match &self.model {
            None => true,
            Some(m) => gen.saturating_sub(m.trained_at_gen) >= policy.retrain_every,
        };
        if stale {
            // cap training set at the backend's kNN capacity via
            // coverage sampling (§III-C)
            let cap = engine.knn_capacity();
            let train_repo = if self.repo.len() > cap {
                sampled_repo(&self.repo, cloud, cap)
            } else {
                self.repo.clone()
            };
            let (model, report) =
                select_and_train(engine, cloud, &train_repo, policy.cv_folds, gen)?;
            self.model = Some(CachedModel {
                trained_at_gen: gen,
                model,
                report,
            });
            metrics.retrains += 1;
        } else {
            metrics.cache_hits += 1;
        }
        Ok(self.model.as_ref().map(|m| m.model.kind))
    }

    /// Full submission loop for one job request: ensure model → decide
    /// configuration (all candidates scored as one featurized batch) →
    /// provision + run → contribute the measurement → account metrics.
    pub fn submit(
        &mut self,
        engine: &mut dyn ModelTrainer,
        cloud: &Cloud,
        policy: &ShardPolicy,
        metrics: &mut Metrics,
        org: &Organization,
        request: &JobRequest,
    ) -> Result<JobOutcome> {
        debug_assert_eq!(request.kind(), self.job, "request routed to wrong shard");
        let model_used = self.ensure_model(engine, cloud, policy, metrics)?;

        // 1) decide a configuration
        let (machine, scaleout, predicted, choice) = match model_used {
            Some(_) => {
                let jm = self.model.as_ref().expect("ensured");
                // candidates only over machine types present in the
                // shared data: the models interpolate, they don't leap
                // across unmeasured memory configurations
                let observed: BTreeSet<String> = self
                    .repo
                    .records()
                    .iter()
                    .map(|r| r.machine.clone())
                    .collect();
                let mut bound = EngineBound {
                    engine: &mut *engine,
                    model: jm.model.clone(),
                };
                let configurator =
                    Configurator::new(cloud).with_machines(observed.into_iter().collect());
                let choice = configurator
                    .configure(&mut bound, request)?
                    .context("empty catalog")?;
                (
                    choice.machine_type.clone(),
                    choice.node_count,
                    choice.predicted_runtime_s,
                    Some(choice),
                )
            }
            None => {
                // cold start: conservative overprovisioning
                let mut oracle = SimOracle::new(self.job, self.rng.next_u64());
                let out = NaiveMax::default().search(cloud, &mut oracle, request)?;
                metrics.fallbacks += 1;
                (out.machine, out.scaleout, f64::NAN, None)
            }
        };

        // 2) provision + run (the cloud access manager step)
        let mut cluster = cloud.provision(&machine, scaleout, &mut self.rng);
        cluster.mark_running();
        let spec_stages = request.spec.stages();
        let mt = cloud.machine(&machine).expect("catalog");
        let sim = crate::sim::Simulator::default();
        let mut run_rng = self.rng.fork(0xEC);
        let actual = sim.run(mt, scaleout, &spec_stages, &mut run_rng).runtime_s;
        cluster.record_busy(actual);
        let held = cluster.terminate();
        let cost = cloud.cost_usd(&machine, scaleout, held);

        // 3) contribute the new record to the shared repository
        let record = RuntimeRecord {
            job: self.job,
            org: org.name.clone(),
            machine: machine.clone(),
            scaleout,
            job_features: request.spec.job_features(),
            runtime_s: actual,
        };
        // duplicate configs are fine at contribution time; merge-level
        // dedup happens when repos are exchanged between parties
        self.repo.contribute(record).map_err(anyhow::Error::msg)?;

        // 4) metrics
        let met_target = request.target_s.map_or(true, |t| actual <= t);
        metrics.submissions += 1;
        metrics.total_cost_usd += cost;
        if request.target_s.is_some() {
            metrics.targets_given += 1;
            if met_target {
                metrics.targets_met += 1;
            }
        }
        let outcome = JobOutcome {
            org: org.name.clone(),
            job: self.job,
            choice,
            machine,
            scaleout,
            model_used,
            predicted_runtime_s: predicted,
            actual_runtime_s: actual,
            actual_cost_usd: cost,
            provisioning_s: cluster.provisioning_delay_s(),
            target_s: request.target_s,
            met_target,
        };
        if !outcome.prediction_error_pct().is_nan() {
            metrics.ape_sum += outcome.prediction_error_pct();
            metrics.ape_count += 1;
        }
        Ok(outcome)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Engine;

    #[test]
    fn cold_shard_has_no_model_and_no_report() {
        let shard = JobShard::new(JobKind::Sort, 1);
        assert_eq!(shard.generation(), 0);
        assert!(shard.trained_at_generation().is_none());
        assert!(shard.selection_report().is_none());
        assert!(shard.repo().is_empty());
    }

    #[test]
    fn ensure_model_respects_cold_start_threshold() {
        let cloud = Cloud::aws_like();
        let mut shard = JobShard::new(JobKind::Sort, 2);
        let mut engine = Engine::native();
        let mut metrics = Metrics::default();
        let policy = ShardPolicy::default();
        let kind = shard
            .ensure_model(&mut engine, &cloud, &policy, &mut metrics)
            .unwrap();
        assert!(kind.is_none(), "empty shard must not train");
        assert_eq!(metrics.retrains, 0);
        assert_eq!(metrics.cache_hits, 0);
    }
}
