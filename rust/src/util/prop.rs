//! Miniature property-testing driver (proptest is not in the offline vendor
//! set). Runs a property over many seeded pseudo-random cases; on failure it
//! reports the failing case index and seed so the case can be replayed
//! exactly, and retries smaller "sizes" first so minimal counterexamples
//! tend to be found early.
//!
//! ```no_run
//! # // no_run: doctest binaries don't inherit the rpath to
//! # // libxla_extension's bundled libstdc++ (cargo quirk); the same
//! # // code path is exercised by the unit tests below.
//! use c3o::util::prop::{forall, Gen};
//! forall("sort_idempotent", 200, |g| {
//!     let mut xs = g.vec_f64(0, 20, -1e3, 1e3);
//!     xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
//!     let once = xs.clone();
//!     xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
//!     assert_eq!(once, xs);
//! });
//! ```

use crate::util::rng::Pcg32;

/// Case-scoped generator handed to properties: wraps the RNG with a
/// "size" that grows over the run, so early cases are small.
pub struct Gen {
    rng: Pcg32,
    /// Grows from 0.1 to 1.0 across the run; generators scale ranges by it.
    pub size: f64,
    pub case: usize,
}

impl Gen {
    /// Uniform usize in `[lo, hi]`, scaled so early cases stay near `lo`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let span = ((hi - lo) as f64 * self.size).ceil() as usize;
        lo + self.rng.index(span.max(1).min(hi - lo + 1))
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Positive f64 in `[lo, hi)`, log-uniform (spans orders of magnitude).
    pub fn f64_log(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        (self.rng.range_f64(lo.ln(), hi.ln())).exp()
    }

    /// Bernoulli.
    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }

    /// Vector of f64 with size-scaled length in `[min_len, max_len]`.
    pub fn vec_f64(&mut self, min_len: usize, max_len: usize, lo: f64, hi: f64) -> Vec<f64> {
        let n = self.usize_in(min_len, max_len);
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Raw RNG access for anything else.
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// Run `prop` over `cases` seeded cases. Panics (failing the enclosing
/// test) with the case index + seed on the first property violation.
pub fn forall<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut prop: F) {
    // Env override for deep soak runs: C3O_PROP_CASES=10000
    let cases = std::env::var("C3O_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(cases);
    let base_seed = std::env::var("C3O_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC30_5EEDu64);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let size = 0.1 + 0.9 * (case as f64 / cases.max(1) as f64);
        let mut g = Gen {
            rng: Pcg32::new(seed),
            size,
            case,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay with C3O_PROP_SEED={base_seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall("addition_commutes", 100, |g| {
            let a = g.f64_in(-1e6, 1e6);
            let b = g.f64_in(-1e6, 1e6);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always_fails' failed")]
    fn reports_failure_with_case() {
        forall("always_fails", 10, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!(x < 0.0, "x = {x}");
        });
    }

    #[test]
    fn sizes_grow() {
        let mut lens = Vec::new();
        forall("size_scaling", 50, |g| {
            lens.push(g.usize_in(0, 1000));
        });
        let early: f64 = lens[..10].iter().sum::<usize>() as f64 / 10.0;
        let late: f64 = lens[40..].iter().sum::<usize>() as f64 / 10.0;
        assert!(late > early, "early {early} late {late}");
    }

    #[test]
    fn log_uniform_spans_magnitudes() {
        let mut below = 0;
        let mut above = 0;
        forall("log_uniform", 200, |g| {
            let x = g.f64_log(1e-3, 1e3);
            if x < 1.0 {
                below += 1;
            } else {
                above += 1;
            }
        });
        assert!(below > 50 && above > 50, "below {below} above {above}");
    }
}
