//! Machine-type catalog.
//!
//! Calibrated to the AWS on-demand price book (us-east-1, Linux) as of the
//! paper's experiments (late 2020), covering the three instance families
//! whose trade-offs drive Fig. 3:
//!
//! * **c5** — compute-optimized: highest clock, 2 GiB RAM per vCPU.
//! * **m5** — general-purpose: 4 GiB RAM per vCPU.
//! * **r5** — memory-optimized: 8 GiB RAM per vCPU.
//!
//! The RAM-per-vCPU ratio is what produces the paper's memory-bottleneck
//! phenomenon (SGD/K-Means spilling at low scale-outs on RAM-lean types),
//! while the price-per-vCPU ordering (c5 < m5 < r5) produces the static
//! cost-efficiency ranking for CPU-bound jobs.

/// Instance family, mirroring the AWS naming the paper's clusters used.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MachineFamily {
    /// Compute optimized (c5-like).
    Compute,
    /// General purpose (m5-like).
    General,
    /// Memory optimized (r5-like).
    Memory,
}

impl MachineFamily {
    /// Short label used in machine names ("c5", "m5", "r5").
    pub fn label(self) -> &'static str {
        match self {
            MachineFamily::Compute => "c5",
            MachineFamily::General => "m5",
            MachineFamily::Memory => "r5",
        }
    }
}

impl std::fmt::Display for MachineFamily {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// One machine type in the catalog.
#[derive(Debug, Clone, PartialEq)]
pub struct MachineType {
    /// Catalog name, e.g. `"m5.xlarge"`.
    pub name: String,
    pub family: MachineFamily,
    /// Virtual CPUs (hyperthreads).
    pub vcpus: u32,
    /// Memory in GiB.
    pub memory_gib: f64,
    /// Single-core relative compute speed (m5 == 1.0; c5 clocks higher).
    pub cpu_perf: f64,
    /// Sequential disk bandwidth per node, MB/s (EBS gp2-like).
    pub disk_mb_s: f64,
    /// Network bandwidth per node, MB/s.
    pub net_mb_s: f64,
    /// On-demand price, USD per hour.
    pub price_usd_hour: f64,
}

impl MachineType {
    /// Memory per vCPU in GiB — the catalog axis behind Fig. 3's
    /// memory-bottleneck exceptions.
    pub fn mem_per_vcpu(&self) -> f64 {
        self.memory_gib / self.vcpus as f64
    }

    /// Price per vCPU-hour, the first-order cost-efficiency axis.
    pub fn price_per_vcpu(&self) -> f64 {
        self.price_usd_hour / self.vcpus as f64
    }
}

impl std::fmt::Display for MachineType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name)
    }
}

fn mt(
    name: &str,
    family: MachineFamily,
    vcpus: u32,
    memory_gib: f64,
    cpu_perf: f64,
    disk_mb_s: f64,
    net_mb_s: f64,
    price_usd_hour: f64,
) -> MachineType {
    MachineType {
        name: name.to_string(),
        family,
        vcpus,
        memory_gib,
        cpu_perf,
        disk_mb_s,
        net_mb_s,
        price_usd_hour,
    }
}

/// The nine-type catalog used by the experiment grid: three families ×
/// three sizes (`large`, `xlarge`, `2xlarge`), prices from the 2020
/// us-east-1 on-demand price book.
pub fn aws_like_catalog() -> Vec<MachineType> {
    use MachineFamily::*;
    vec![
        // name             family   vcpu  mem    perf  disk   net    $/h
        mt("c5.large", Compute, 2, 4.0, 1.15, 160.0, 90.0, 0.085),
        mt("c5.xlarge", Compute, 4, 8.0, 1.15, 160.0, 160.0, 0.170),
        mt("c5.2xlarge", Compute, 8, 16.0, 1.15, 220.0, 320.0, 0.340),
        mt("m5.large", General, 2, 8.0, 1.0, 160.0, 90.0, 0.096),
        mt("m5.xlarge", General, 4, 16.0, 1.0, 160.0, 160.0, 0.192),
        mt("m5.2xlarge", General, 8, 32.0, 1.0, 220.0, 320.0, 0.384),
        mt("r5.large", Memory, 2, 16.0, 1.0, 160.0, 90.0, 0.126),
        mt("r5.xlarge", Memory, 4, 32.0, 1.0, 160.0, 160.0, 0.252),
        mt("r5.2xlarge", Memory, 8, 64.0, 1.0, 220.0, 320.0, 0.504),
    ]
}

/// The subset of the catalog used for the Table-I experiment grid's
/// machine-type axis (one size per family keeps the grid at the paper's
/// scale; the full catalog is exercised by the configurator benches).
pub fn grid_machine_types() -> Vec<String> {
    vec![
        "c5.xlarge".to_string(),
        "m5.xlarge".to_string(),
        "r5.xlarge".to_string(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_ram_ratios() {
        for m in aws_like_catalog() {
            let want = match m.family {
                MachineFamily::Compute => 2.0,
                MachineFamily::General => 4.0,
                MachineFamily::Memory => 8.0,
            };
            assert!(
                (m.mem_per_vcpu() - want).abs() < 1e-9,
                "{}: mem/vcpu {}",
                m.name,
                m.mem_per_vcpu()
            );
        }
    }

    #[test]
    fn price_per_vcpu_ordering() {
        // c5 cheapest per vCPU, r5 most expensive — the Fig. 3 driver.
        let cat = aws_like_catalog();
        let get = |n: &str| cat.iter().find(|m| m.name == n).unwrap().price_per_vcpu();
        assert!(get("c5.xlarge") < get("m5.xlarge"));
        assert!(get("m5.xlarge") < get("r5.xlarge"));
    }

    #[test]
    fn doubling_size_doubles_price() {
        let cat = aws_like_catalog();
        let get = |n: &str| cat.iter().find(|m| m.name == n).unwrap();
        for fam in ["c5", "m5", "r5"] {
            let x = get(&format!("{fam}.xlarge"));
            let xx = get(&format!("{fam}.2xlarge"));
            assert!((xx.price_usd_hour - 2.0 * x.price_usd_hour).abs() < 1e-9);
            assert_eq!(xx.vcpus, 2 * x.vcpus);
            assert!((xx.memory_gib - 2.0 * x.memory_gib).abs() < 1e-9);
        }
    }

    #[test]
    fn grid_types_exist_in_catalog() {
        let cat = aws_like_catalog();
        for name in grid_machine_types() {
            assert!(cat.iter().any(|m| m.name == name), "{name} missing");
        }
    }
}
