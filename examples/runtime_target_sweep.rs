//! Runtime-target sweep: how the configurator trades cost against the
//! user's deadline (paper Fig. 1's "runtime target" input).
//!
//! For one Sort job, sweep the target from very tight to very loose and
//! print the chosen configuration, predicted runtime, and expected cost
//! at each point — the cost/deadline frontier a C3O user navigates.
//!
//! Run with: `make artifacts && cargo run --release --example runtime_target_sweep`

use c3o::models::BoundModel;
use c3o::prelude::*;

fn main() -> anyhow::Result<()> {
    let artifacts = c3o::runtime::Runtime::default_dir();
    if !c3o::runtime::Runtime::artifacts_available(&artifacts) {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let cloud = Cloud::aws_like();

    println!("building the Sort shared corpus...");
    let grid = ExperimentGrid {
        experiments: ExperimentGrid::paper_table1()
            .experiments
            .into_iter()
            .filter(|e| e.spec.kind() == JobKind::Sort)
            .collect(),
        repetitions: 5,
    };
    let repo = grid.execute(&cloud, 42).repo_for(JobKind::Sort);

    let mut predictor = Predictor::new(&artifacts)?;
    let (model, report) =
        c3o::models::selection::select_and_train(&mut predictor, &cloud, &repo, 4, 1)?;
    println!(
        "model: {} (CV MAPE pessimistic {:.1}% / optimistic {:.1}%)\n",
        report.chosen.name(),
        report.mape_of(ModelKind::Pessimistic),
        report.mape_of(ModelKind::Optimistic)
    );

    let configurator = Configurator::new(&cloud);
    println!(
        "{:>9} {:>12} {:>4} {:>11} {:>10} {:>6}",
        "target_s", "machine", "n", "predicted_s", "cost_usd", "met"
    );
    let spec_gb = 17.0;
    for target in [60.0, 120.0, 180.0, 240.0, 300.0, 420.0, 600.0, 900.0, 1800.0] {
        let request = JobRequest::sort(spec_gb).with_target_seconds(target);
        let mut bound = BoundModel {
            predictor: &mut predictor,
            model: model.clone(),
        };
        let choice = configurator
            .configure(&mut bound, &request)?
            .expect("catalog nonempty");
        println!(
            "{:>9.0} {:>12} {:>4} {:>11.1} {:>10.3} {:>6}",
            target,
            choice.machine_type,
            choice.node_count,
            choice.predicted_runtime_s,
            choice.expected_cost_usd,
            choice.meets_target
        );
    }

    println!(
        "\nNote how looser targets let the configurator drop to smaller/cheaper\n\
         clusters, while very tight targets force the fastest configuration even\n\
         when the deadline is unattainable (met = false)."
    );
    Ok(())
}
