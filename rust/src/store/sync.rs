//! Peer delta-sync: convergent runtime-data exchange between
//! independently-running C3O deployments.
//!
//! One entry point, [`sync`], drives a full bidirectional exchange
//! between two [`Client`]s; [`SyncOptions`] picks the three orthogonal
//! knobs instead of a function per combination:
//!
//! * [`SyncScope`] — one job kind, an explicit list, or every kind.
//! * [`SyncDetail`] — folded totals, or the per-(job, org) breakdown
//!   the `c3o sync --json` CLI renders.
//! * [`SyncProtocol`] — the wire generation:
//!   - **`V3`** (record-level, per job): `Watermarks` → `SyncPull` →
//!     `SyncPush` per job kind. Prefix-aligned op logs ship O(changed
//!     records); a digest mismatch falls back to whole-org ops; a peer
//!     below the responder's truncation floor receives a whole-org
//!     [`crate::repo::OrgSnapshot`] instead (its records count into
//!     [`SyncStats::offered`], the adoption into
//!     [`SyncStats::snapshots`]).
//!   - **`BatchedV4`** (record-level, cross-job): one
//!     `WatermarksAll`/`SyncPullAll`/`SyncPushAll` conversation covers
//!     *all* requested job kinds — [`SyncStats::round_trips`] stays
//!     constant in the job-kind count, where `V3` pays per job. The
//!     push replies carry post-apply watermarks, which is how mesh
//!     peers learn ack positions ([`crate::store::mesh`]).
//!   - **`V2`** (legacy, org-granular holdings): for deployments that
//!     predate the op log, served via the [`crate::api::compat`]
//!     adapter. A changed org ships whole — which also makes v2 peers
//!     naturally safe against truncated logs: holdings summaries never
//!     reference folded history.
//!
//! Merge-level dedup with deterministic conflict resolution makes every
//! protocol idempotent and convergent: repeated exchanges drive any set
//! of peers to **bitwise-identical** repositories regardless of gossip
//! order — with acked-floor truncation active included, because digests
//! are cumulative from genesis across the fold (property-tested in
//! `rust/tests/federation.rs`).
//!
//! Scheduling lives elsewhere: [`SyncDriver`] (below) repeats a
//! fixed-peer-list exchange on a background thread, and the mesh layer
//! ([`crate::store::mesh`]) replaces that static loop with
//! roster-driven fanout selection, batched exchange, and ack tracking.

use crate::api::{ApiError, Client, SyncDelta, WatermarkSet};
use crate::workloads::JobKind;
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Counters from one or more sync exchanges.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SyncStats {
    /// `SyncPull`-class delta extractions issued (per-job pulls, or
    /// batched cross-job pulls — each counts once).
    pub pulls: u64,
    /// Requests issued to either deployment over the whole exchange —
    /// the wire cost. The batched protocol's reason to exist: constant
    /// in the job-kind count, where per-job sync pays per kind.
    pub round_trips: u64,
    /// Records applied locally (adds + replacements).
    pub records_in: u64,
    /// Records the peer applied from us.
    pub records_out: u64,
    /// Ops (or snapshot records) shipped over the wire in either
    /// direction, applied or not.
    pub offered: u64,
    /// Ops shipped but not applied: already-seen re-deliveries plus
    /// merge-rejected (seen) ops.
    pub skipped: u64,
    /// Whole-org snapshot fallbacks shipped (a receiver sat below the
    /// sender's truncation floor, or logs diverged beyond op repair).
    pub snapshots: u64,
    /// Runtime disagreements surfaced by either side.
    pub conflicts: u64,
    /// Exchanges that failed (driver keeps going; the next tick retries).
    pub errors: u64,
    /// Wall-time spent inside pull round trips, nanoseconds.
    /// Observability only — never feeds a protocol decision.
    pub pull_nanos: u64,
    /// Wall-time spent inside push round trips (which include the
    /// receiver's merge/apply), nanoseconds. Observability only.
    pub push_nanos: u64,
}

impl SyncStats {
    /// Accumulate another stats block.
    pub fn fold(&mut self, other: &SyncStats) {
        self.pulls += other.pulls;
        self.round_trips += other.round_trips;
        self.records_in += other.records_in;
        self.records_out += other.records_out;
        self.offered += other.offered;
        self.skipped += other.skipped;
        self.snapshots += other.snapshots;
        self.conflicts += other.conflicts;
        self.errors += other.errors;
        self.pull_nanos += other.pull_nanos;
        self.push_nanos += other.push_nanos;
    }

    /// True when the exchange *changed* no repository in either
    /// direction — the peers hold converged (merge-equivalent) data for
    /// the synced jobs.
    pub fn quiescent(&self) -> bool {
        self.records_in == 0 && self.records_out == 0
    }
}

/// Per-organization accounting of one or more exchanges: how many ops
/// of this org's log were offered over the wire, how many the receiver
/// applied, and how many it skipped (seen/duplicate). The
/// `c3o sync --json` breakdown.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OrgExchange {
    pub offered: u64,
    pub applied: u64,
    pub skipped: u64,
}

impl OrgExchange {
    /// Accumulate another exchange's counters (rounds, directions).
    pub fn fold(&mut self, other: &OrgExchange) {
        self.offered += other.offered;
        self.applied += other.applied;
        self.skipped += other.skipped;
    }
}

/// Per-org exchange accounting, folded across directions and rounds.
pub type OrgExchangeMap = BTreeMap<String, OrgExchange>;

/// Fold one per-org map into another (the accumulation the driver and
/// the `c3o sync` CLI both perform across rounds).
pub fn fold_orgs(into: &mut OrgExchangeMap, from: &OrgExchangeMap) {
    for (org, x) in from {
        into.entry(org.clone()).or_default().fold(x);
    }
}

// ---------------------------------------------------------------------------
// the one sync entry point
// ---------------------------------------------------------------------------

/// Which job repositories an exchange covers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SyncScope {
    /// One job kind.
    Job(JobKind),
    /// An explicit list, exchanged in the given order.
    Jobs(Vec<JobKind>),
    /// Every [`JobKind::all`] kind.
    All,
}

impl SyncScope {
    fn jobs(&self) -> Vec<JobKind> {
        match self {
            SyncScope::Job(job) => vec![*job],
            SyncScope::Jobs(jobs) => jobs.clone(),
            SyncScope::All => JobKind::all().to_vec(),
        }
    }
}

/// How much accounting an exchange returns.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum SyncDetail {
    /// Folded [`SyncStats`] only; [`SyncSummary::by_job`] stays empty.
    #[default]
    Totals,
    /// Additionally the per-(job, org) [`OrgExchangeMap`] breakdown.
    PerOrg,
}

/// Which wire generation an exchange speaks.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum SyncProtocol {
    /// Record-level op-log deltas, one conversation per job kind.
    #[default]
    V3,
    /// Record-level op-log deltas, one batched conversation for every
    /// job kind in scope (v4).
    BatchedV4,
    /// Legacy org-granular holdings exchange (no per-org breakdown —
    /// v2 deltas carry bare records, not attributed ops).
    V2,
}

/// The three orthogonal knobs of one [`sync`] call.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SyncOptions {
    pub scope: SyncScope,
    pub detail: SyncDetail,
    pub protocol: SyncProtocol,
}

impl Default for SyncOptions {
    /// Every job kind, totals only, current per-job protocol.
    fn default() -> SyncOptions {
        SyncOptions {
            scope: SyncScope::All,
            detail: SyncDetail::Totals,
            protocol: SyncProtocol::V3,
        }
    }
}

/// The one coherent result of a [`sync`] exchange: folded stats plus
/// (when [`SyncDetail::PerOrg`] was requested) the per-(job, org)
/// breakdown.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SyncSummary {
    pub stats: SyncStats,
    pub by_job: BTreeMap<JobKind, OrgExchangeMap>,
}

/// One full bidirectional exchange between `local` and `peer`.
///
/// Inbound first: pull the peer's delta against local marks and apply
/// it. Outbound second, *after* the inbound apply, so ops just learned
/// (that the peer already holds) are not echoed back. Both directions
/// reuse merge's dedup, so the exchange is idempotent; repeating it
/// until [`SyncStats::quiescent`] drives both sides to convergence.
pub fn sync(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    options: &SyncOptions,
) -> Result<SyncSummary, ApiError> {
    let mut summary = SyncSummary::default();
    match options.protocol {
        SyncProtocol::V3 => {
            for job in options.scope.jobs() {
                let mut orgs = OrgExchangeMap::new();
                let peer_marks = exchange_direction(
                    local,
                    peer,
                    job,
                    None,
                    true,
                    &mut summary.stats,
                    &mut orgs,
                )?;
                exchange_direction(
                    peer,
                    local,
                    job,
                    Some(peer_marks),
                    false,
                    &mut summary.stats,
                    &mut orgs,
                )?;
                settle_orgs(&mut orgs);
                if options.detail == SyncDetail::PerOrg {
                    fold_orgs(summary.by_job.entry(job).or_default(), &orgs);
                }
            }
        }
        SyncProtocol::BatchedV4 => {
            sync_batched(local, peer, &options.scope.jobs(), options.detail, &mut summary)?;
        }
        SyncProtocol::V2 => {
            for job in options.scope.jobs() {
                sync_v2_job(local, peer, job, &mut summary.stats)?;
            }
        }
    }
    Ok(summary)
}

/// Per-org skipped counts are derived, not wire-carried: whatever was
/// offered for an org but not applied was skipped.
fn settle_orgs(orgs: &mut OrgExchangeMap) {
    for x in orgs.values_mut() {
        x.skipped = x.offered.saturating_sub(x.applied);
    }
}

/// One direction of a v3 exchange: pull the delta `dst` is missing from
/// `src` (against `dst_marks`, or a fresh `Watermarks` read when
/// `None`), push it into `dst`, account per org — crediting
/// `records_in` when `inbound`, `records_out` otherwise. Returns the
/// source's marks from the pull reply, priming the reverse direction.
fn exchange_direction(
    dst: &mut dyn Client,
    src: &mut dyn Client,
    job: JobKind,
    dst_marks: Option<BTreeMap<String, crate::repo::OrgWatermark>>,
    inbound: bool,
    stats: &mut SyncStats,
    orgs: &mut OrgExchangeMap,
) -> Result<BTreeMap<String, crate::repo::OrgWatermark>, ApiError> {
    let marks = match dst_marks {
        Some(marks) => marks,
        None => {
            stats.round_trips += 1;
            dst.watermarks(job)?.watermarks
        }
    };
    let pull_started = std::time::Instant::now();
    let delta = src.sync_pull(job, marks)?;
    stats.pull_nanos += pull_started.elapsed().as_nanos() as u64;
    stats.pulls += 1;
    stats.round_trips += 1;
    let src_marks = delta.watermarks.clone();
    stats.offered += delta.ops.len() as u64;
    for op in &delta.ops {
        orgs.entry(op.org.clone()).or_default().offered += 1;
    }
    stats.snapshots += delta.snapshots.len() as u64;
    for snap in &delta.snapshots {
        stats.offered += snap.records.len() as u64;
        orgs.entry(snap.org.clone()).or_default().offered += snap.records.len() as u64;
    }
    if !delta.ops.is_empty() || !delta.snapshots.is_empty() {
        let push_started = std::time::Instant::now();
        let report = dst.sync_push_full(job, delta.ops, delta.snapshots)?;
        stats.push_nanos += push_started.elapsed().as_nanos() as u64;
        stats.round_trips += 1;
        let applied = if inbound {
            &mut stats.records_in
        } else {
            &mut stats.records_out
        };
        *applied += report.changed() as u64;
        stats.skipped += report.skipped as u64;
        stats.conflicts += report.conflicts.len() as u64;
        for (org, applied) in &report.applied_by_org {
            orgs.entry(org.clone()).or_default().applied += applied;
        }
    }
    Ok(src_marks)
}

/// Account one batched direction's deltas into stats + per-org maps.
fn account_deltas(
    deltas: &[SyncDelta],
    stats: &mut SyncStats,
    by_job: &mut BTreeMap<JobKind, OrgExchangeMap>,
    detail: SyncDetail,
) {
    for delta in deltas {
        stats.offered += delta.ops.len() as u64;
        stats.snapshots += delta.snapshots.len() as u64;
        stats.offered += delta
            .snapshots
            .iter()
            .map(|s| s.records.len() as u64)
            .sum::<u64>();
        if detail == SyncDetail::PerOrg {
            let orgs = by_job.entry(delta.job).or_default();
            for op in &delta.ops {
                orgs.entry(op.org.clone()).or_default().offered += 1;
            }
            for snap in &delta.snapshots {
                orgs.entry(snap.org.clone()).or_default().offered += snap.records.len() as u64;
            }
        }
    }
}

/// The batched (v4) bidirectional exchange: all of `jobs` in one
/// `WatermarksAll` → `SyncPullAll` → `SyncPushAll` conversation per
/// direction — five job kinds for the round-trip price of one.
fn sync_batched(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    jobs: &[JobKind],
    detail: SyncDetail,
    summary: &mut SyncSummary,
) -> Result<(), ApiError> {
    let in_scope = |set: &WatermarkSet| jobs.contains(&set.job);
    let stats = &mut summary.stats;

    // inbound: the peer's cross-job delta against our marks
    let ours: Vec<WatermarkSet> = local.watermarks_all()?.into_iter().filter(in_scope).collect();
    stats.round_trips += 1;
    let pull_started = std::time::Instant::now();
    let deltas = peer.sync_pull_all(ours)?;
    stats.pull_nanos += pull_started.elapsed().as_nanos() as u64;
    stats.pulls += 1;
    stats.round_trips += 1;
    // the pull reply carries the peer's own marks per job — the
    // outbound direction needs no extra watermark read
    let peer_marks: Vec<WatermarkSet> = deltas
        .iter()
        .map(|d| WatermarkSet {
            job: d.job,
            generation: d.generation,
            watermarks: d.watermarks.clone(),
        })
        .collect();
    account_deltas(&deltas, stats, &mut summary.by_job, detail);
    if deltas.iter().any(|d| !d.ops.is_empty() || !d.snapshots.is_empty()) {
        let push_started = std::time::Instant::now();
        let applied = local.sync_push_all(deltas)?;
        stats.push_nanos += push_started.elapsed().as_nanos() as u64;
        stats.round_trips += 1;
        for report in &applied.reports {
            stats.records_in += report.changed() as u64;
            stats.skipped += report.skipped as u64;
            stats.conflicts += report.conflicts.len() as u64;
            if detail == SyncDetail::PerOrg {
                let orgs = summary.by_job.entry(report.job).or_default();
                for (org, applied) in &report.applied_by_org {
                    orgs.entry(org.clone()).or_default().applied += applied;
                }
            }
        }
    }

    // outbound: our cross-job delta against the peer's marks, after
    // the inbound apply so fresh ops are not echoed back
    let deltas = local.sync_pull_all(peer_marks)?;
    stats.pulls += 1;
    stats.round_trips += 1;
    account_deltas(&deltas, stats, &mut summary.by_job, detail);
    if deltas.iter().any(|d| !d.ops.is_empty() || !d.snapshots.is_empty()) {
        let push_started = std::time::Instant::now();
        let applied = peer.sync_push_all(deltas)?;
        stats.push_nanos += push_started.elapsed().as_nanos() as u64;
        stats.round_trips += 1;
        for report in &applied.reports {
            stats.records_out += report.changed() as u64;
            stats.skipped += report.skipped as u64;
            stats.conflicts += report.conflicts.len() as u64;
            if detail == SyncDetail::PerOrg {
                let orgs = summary.by_job.entry(report.job).or_default();
                for (org, applied) in &report.applied_by_org {
                    orgs.entry(org.clone()).or_default().applied += applied;
                }
            }
        }
    }
    for orgs in summary.by_job.values_mut() {
        settle_orgs(orgs);
    }
    Ok(())
}

/// One job's bidirectional exchange over the **legacy v2** org-granular
/// protocol: a changed org ships whole, and blind-duplicate holders are
/// re-offered forever. Kept to interoperate with pre-op-log deployments
/// and as the comparison baseline of `benches/sync_throughput.rs`.
fn sync_v2_job(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    job: JobKind,
    stats: &mut SyncStats,
) -> Result<(), ApiError> {
    let ours = local.watermarks_v2(job)?;
    stats.round_trips += 1;
    let pull_started = std::time::Instant::now();
    let delta = peer.sync_pull_v2(job, ours.watermarks)?;
    stats.pull_nanos += pull_started.elapsed().as_nanos() as u64;
    stats.pulls += 1;
    stats.round_trips += 1;
    let peer_marks = delta.watermarks.clone();
    stats.offered += delta.records.len() as u64;
    if !delta.records.is_empty() {
        let push_started = std::time::Instant::now();
        let report = local.sync_push_v2(job, delta.records)?;
        stats.push_nanos += push_started.elapsed().as_nanos() as u64;
        stats.round_trips += 1;
        stats.records_in += report.changed() as u64;
        stats.skipped += report.skipped as u64;
        stats.conflicts += report.conflicts.len() as u64;
    }

    let out = local.sync_pull_v2(job, peer_marks)?;
    stats.pulls += 1;
    stats.round_trips += 1;
    stats.offered += out.records.len() as u64;
    if !out.records.is_empty() {
        let push_started = std::time::Instant::now();
        let report = peer.sync_push_v2(job, out.records)?;
        stats.push_nanos += push_started.elapsed().as_nanos() as u64;
        stats.round_trips += 1;
        stats.records_out += report.changed() as u64;
        stats.skipped += report.skipped as u64;
        stats.conflicts += report.conflicts.len() as u64;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// deprecated per-combination shims
// ---------------------------------------------------------------------------

/// One full bidirectional exchange for one job kind, with per-org
/// accounting.
#[deprecated(note = "use sync() with SyncOptions { scope: Job, detail: PerOrg, .. }")]
pub fn sync_job_detailed(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    job: JobKind,
) -> Result<(SyncStats, OrgExchangeMap), ApiError> {
    let summary = sync(
        local,
        peer,
        &SyncOptions {
            scope: SyncScope::Job(job),
            detail: SyncDetail::PerOrg,
            protocol: SyncProtocol::V3,
        },
    )?;
    let orgs = summary.by_job.get(&job).cloned().unwrap_or_default();
    Ok((summary.stats, orgs))
}

/// One full bidirectional exchange for one job kind.
#[deprecated(note = "use sync() with SyncOptions { scope: Job, .. }")]
pub fn sync_job(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    job: JobKind,
) -> Result<SyncStats, ApiError> {
    sync(
        local,
        peer,
        &SyncOptions {
            scope: SyncScope::Job(job),
            ..SyncOptions::default()
        },
    )
    .map(|summary| summary.stats)
}

/// One full bidirectional exchange over the legacy v2 org-granular
/// protocol.
#[deprecated(note = "use sync() with SyncOptions { protocol: V2, .. }")]
pub fn sync_job_v2(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    job: JobKind,
) -> Result<SyncStats, ApiError> {
    sync(
        local,
        peer,
        &SyncOptions {
            scope: SyncScope::Job(job),
            detail: SyncDetail::Totals,
            protocol: SyncProtocol::V2,
        },
    )
    .map(|summary| summary.stats)
}

/// Bidirectional exchange over several job kinds, stats folded.
#[deprecated(note = "use sync() with SyncOptions { scope: Jobs, .. }")]
pub fn sync_all(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    jobs: &[JobKind],
) -> Result<SyncStats, ApiError> {
    sync(
        local,
        peer,
        &SyncOptions {
            scope: SyncScope::Jobs(jobs.to_vec()),
            ..SyncOptions::default()
        },
    )
    .map(|summary| summary.stats)
}

/// Bidirectional exchange over several job kinds: folded stats plus the
/// per-(job, org) breakdown.
#[deprecated(note = "use sync() with SyncOptions { scope: Jobs, detail: PerOrg, .. }")]
pub fn sync_all_detailed(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    jobs: &[JobKind],
) -> Result<(SyncStats, BTreeMap<JobKind, OrgExchangeMap>), ApiError> {
    sync(
        local,
        peer,
        &SyncOptions {
            scope: SyncScope::Jobs(jobs.to_vec()),
            detail: SyncDetail::PerOrg,
            protocol: SyncProtocol::V3,
        },
    )
    .map(|summary| (summary.stats, summary.by_job))
}

// ---------------------------------------------------------------------------
// the fixed-peer-list background loop
// ---------------------------------------------------------------------------

/// Background gossip loop over a **static** peer list: exchanges deltas
/// between a local deployment and each peer at a fixed interval, on its
/// own thread. The mesh-scheduled successor — roster-driven fanout,
/// batched exchange, ack tracking — is
/// [`MeshDriver`](crate::store::mesh::MeshDriver); this driver remains
/// for hand-wired two-deployment setups and as the simplest harness.
///
/// The driver holds plain [`Client`] handles (e.g.
/// [`ServiceClient`](crate::coordinator::service::ServiceClient)s), so
/// it composes with any deployment. A failed exchange is counted and
/// retried on the next tick; a peer answering
/// [`ApiError::Stopped`] ends the loop (the deployment is gone).
pub struct SyncDriver {
    stop: mpsc::Sender<()>,
    handle: Option<JoinHandle<SyncStats>>,
}

impl SyncDriver {
    /// Spawn the loop: one immediate round, then one round per
    /// `interval` until [`SyncDriver::stop`].
    pub fn spawn<C: Client + Send + 'static>(
        mut local: C,
        mut peers: Vec<C>,
        jobs: Vec<JobKind>,
        interval: Duration,
    ) -> SyncDriver {
        let (stop_tx, stop_rx) = mpsc::channel::<()>();
        let handle = std::thread::spawn(move || {
            let options = SyncOptions {
                scope: SyncScope::Jobs(jobs),
                ..SyncOptions::default()
            };
            let mut total = SyncStats::default();
            loop {
                for peer in peers.iter_mut() {
                    match sync(&mut local, peer, &options) {
                        Ok(summary) => total.fold(&summary.stats),
                        Err(ApiError::Stopped) => return total,
                        Err(_) => total.errors += 1,
                    }
                }
                match stop_rx.recv_timeout(interval) {
                    // stop requested, or the driver handle is gone
                    Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => return total,
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                }
            }
        });
        SyncDriver {
            stop: stop_tx,
            handle: Some(handle),
        }
    }

    /// Stop the loop and return the accumulated stats.
    pub fn stop(mut self) -> SyncStats {
        self.stop_inner()
    }

    fn stop_inner(&mut self) -> SyncStats {
        let _ = self.stop.send(());
        match self.handle.take() {
            Some(handle) => handle.join().unwrap_or_default(),
            None => SyncStats::default(),
        }
    }
}

impl Drop for SyncDriver {
    fn drop(&mut self) {
        self.stop_inner();
    }
}
