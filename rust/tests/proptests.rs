//! Property-based tests over coordinator-layer invariants (the in-house
//! `util::prop` driver stands in for proptest, which the offline vendor
//! set lacks). Each property runs over hundreds of seeded random cases;
//! failures report the case index + replay seed.

use c3o::cloud::{BillingPolicy, Cloud};
use c3o::configurator::{Configurator, JobRequest};
use c3o::models::native::NativeEngine;
use c3o::models::oracle::SimOracle;
use c3o::models::{ConfigQuery, ModelKind, ModelTrainer, QueryBatch, RuntimeModel};
use c3o::repo::{RuntimeDataRepo, RuntimeRecord};
use c3o::sim::{SimConfig, Simulator};
use c3o::util::prop::{forall, Gen};
use c3o::util::stats;
use c3o::workloads::{JobKind, JobSpec};
use std::collections::BTreeSet;

fn random_record(g: &mut Gen, kind: JobKind) -> RuntimeRecord {
    let machines = ["c5.xlarge", "m5.xlarge", "r5.xlarge"];
    let nf = kind.feature_names().len();
    RuntimeRecord {
        job: kind,
        org: format!("org{}", g.usize_in(0, 8)),
        machine: machines[g.usize_in(0, 2)].to_string(),
        scaleout: g.usize_in(2, 12) as u32,
        job_features: (0..nf).map(|_| g.f64_in(0.5, 30.0)).collect(),
        runtime_s: g.f64_log(10.0, 5000.0),
    }
}

// --------------------------------------------------------------------------
// Repository invariants
// --------------------------------------------------------------------------

#[test]
fn merge_is_idempotent() {
    forall("merge_idempotent", 150, |g| {
        let kind = *g.pick(&JobKind::all());
        let mut a = RuntimeDataRepo::new(kind);
        let mut b = RuntimeDataRepo::new(kind);
        for _ in 0..g.usize_in(0, 25) {
            let _ = a.contribute(random_record(g, kind));
        }
        for _ in 0..g.usize_in(0, 25) {
            let _ = b.contribute(random_record(g, kind));
        }
        let mut once = a.fork();
        once.merge(&b).unwrap();
        let n1 = once.len();
        once.merge(&b).unwrap();
        assert_eq!(once.len(), n1, "second merge must add nothing");
    });
}

#[test]
fn merge_result_is_order_independent_as_set() {
    forall("merge_commutative_as_set", 150, |g| {
        let kind = JobKind::Grep;
        let mut a = RuntimeDataRepo::new(kind);
        let mut b = RuntimeDataRepo::new(kind);
        for _ in 0..g.usize_in(0, 20) {
            let _ = a.contribute(random_record(g, kind));
        }
        for _ in 0..g.usize_in(0, 20) {
            let _ = b.contribute(random_record(g, kind));
        }
        let mut ab = a.fork();
        ab.merge(&b).unwrap();
        let mut ba = b.fork();
        ba.merge(&a).unwrap();
        let keys = |r: &RuntimeDataRepo| -> BTreeSet<String> {
            r.records().iter().map(|x| x.config_key()).collect()
        };
        assert_eq!(keys(&ab), keys(&ba));
    });
}

#[test]
fn csv_round_trip_is_lossless() {
    forall("csv_round_trip", 100, |g| {
        let kind = *g.pick(&JobKind::all());
        let mut repo = RuntimeDataRepo::new(kind);
        for _ in 0..g.usize_in(1, 30) {
            let _ = repo.contribute(random_record(g, kind));
        }
        let table = repo.to_table();
        let back = RuntimeDataRepo::from_table(kind, &table).unwrap();
        assert_eq!(back.len(), repo.len());
        for (x, y) in repo.records().iter().zip(back.records()) {
            assert_eq!(x.org, y.org);
            assert_eq!(x.machine, y.machine);
            assert_eq!(x.scaleout, y.scaleout);
            assert!((x.runtime_s - y.runtime_s).abs() < 1e-9 * x.runtime_s.max(1.0));
            for (fa, fb) in x.job_features.iter().zip(&y.job_features) {
                assert!((fa - fb).abs() < 1e-9 * fa.abs().max(1.0));
            }
        }
    });
}

// --------------------------------------------------------------------------
// Incremental feature-matrix cache invariants
// --------------------------------------------------------------------------

/// The cache's training inputs must be BITWISE equal to featurizing from
/// scratch — f32 accumulation is order-sensitive, so this is the whole
/// contract that makes the incremental path a pure optimization.
fn assert_feature_fit_bits_equal(
    scratch: &(c3o::repo::FeatureSpace, c3o::util::matrix::MatF32, Vec<f32>),
    cached: &(c3o::repo::FeatureSpace, c3o::util::matrix::MatF32, Vec<f32>),
    context: &str,
) {
    let (fs, fx, fy) = scratch;
    let (cs, cx, cy) = cached;
    assert_eq!(fs.names, cs.names, "{context}: feature names");
    assert_eq!(fs.mean.len(), cs.mean.len(), "{context}: mean dim");
    for (i, (a, b)) in fs.mean.iter().zip(&cs.mean).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{context}: mean[{i}] {a} vs {b}");
    }
    for (i, (a, b)) in fs.sd.iter().zip(&cs.sd).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{context}: sd[{i}] {a} vs {b}");
    }
    assert_eq!(fs.y_mean.to_bits(), cs.y_mean.to_bits(), "{context}: y_mean");
    assert_eq!(fs.y_sd.to_bits(), cs.y_sd.to_bits(), "{context}: y_sd");
    assert_eq!((fx.rows, fx.cols), (cx.rows, cx.cols), "{context}: x shape");
    for (i, (a, b)) in fx.data.iter().zip(&cx.data).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{context}: x.data[{i}] {a} vs {b}");
    }
    assert_eq!(fy.len(), cy.len(), "{context}: y len");
    for (i, (a, b)) in fy.iter().zip(cy).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{context}: y[{i}] {a} vs {b}");
    }
}

#[test]
fn feature_cache_is_bitwise_equal_across_random_mutation_sequences() {
    // Random interleavings of every repo mutation the op log knows —
    // contribute, bulk merge (adds + conflict replacements), record-level
    // sync deltas, canonical reorders — replayed incrementally into the
    // cache, must yield training inputs bitwise-identical to refitting
    // from scratch after every step.
    use c3o::repo::{FeatureMatrixCache, Featurizer};
    let cloud = Cloud::aws_like();
    forall("feature_cache_bitwise", 80, |g| {
        let kind = *g.pick(&JobKind::all());
        let featurizer = Featurizer::new(&cloud);
        let mut repo = RuntimeDataRepo::new(kind);
        let mut cache = FeatureMatrixCache::new();
        for _ in 0..g.usize_in(1, 6) {
            let _ = repo.contribute(random_record(g, kind));
        }
        for step in 0..g.usize_in(2, 10) {
            let op = g.usize_in(0, 3);
            match op {
                0 => {
                    for _ in 0..g.usize_in(1, 4) {
                        let _ = repo.contribute(random_record(g, kind));
                    }
                }
                1 => {
                    // bulk merge: fresh peer rows, plus (sometimes) a
                    // re-measurement of a config the repo already holds,
                    // exercising the conflict/replace path
                    let mut peer = RuntimeDataRepo::new(kind);
                    for _ in 0..g.usize_in(1, 4) {
                        let _ = peer.contribute(random_record(g, kind));
                    }
                    if g.bool() && !repo.is_empty() {
                        let mut again =
                            repo.records()[g.usize_in(0, repo.len() - 1)].clone();
                        again.org = format!("re-{}", again.org);
                        again.runtime_s *= g.f64_in(0.5, 1.5);
                        let _ = peer.contribute(again);
                    }
                    repo.merge(&peer).unwrap();
                }
                2 => {
                    // record-level sync delta from a diverged fork
                    let mut peer = repo.fork();
                    for _ in 0..g.usize_in(1, 3) {
                        let _ = peer.contribute(random_record(g, kind));
                    }
                    let ops = peer.delta_for(&repo.watermarks());
                    repo.apply_sync_ops(&ops).unwrap();
                }
                _ => repo.canonicalize(),
            }
            let reused = cache.refresh(&featurizer, &repo);
            assert!(reused <= repo.len(), "reuse count is bounded by the corpus");
            let scratch = featurizer.fit(&repo);
            let cached = cache.fit(&repo);
            assert_feature_fit_bits_equal(
                &scratch,
                &cached,
                &format!("case {} step {step} op {op}", g.case),
            );
        }
    });
}

// --------------------------------------------------------------------------
// Billing invariants
// --------------------------------------------------------------------------

#[test]
fn billing_is_monotone_and_respects_minimum() {
    forall("billing_monotone", 300, |g| {
        let policy = BillingPolicy::per_second_with_minimum(g.usize_in(0, 120) as u64);
        let t1 = g.f64_in(0.0, 5000.0);
        let t2 = t1 + g.f64_in(0.0, 5000.0);
        let price = g.f64_in(0.01, 10.0);
        let n = g.usize_in(1, 64) as u32;
        let c1 = policy.cost_usd(price, n, t1);
        let c2 = policy.cost_usd(price, n, t2);
        assert!(c2 >= c1 - 1e-12, "cost must be monotone in time");
        let floor = policy.cost_usd(price, n, 0.0);
        assert!(c1 >= floor - 1e-12, "minimum charge applies");
    });
}

// --------------------------------------------------------------------------
// Simulator invariants
// --------------------------------------------------------------------------

#[test]
fn simulator_runtime_monotone_in_data_size() {
    let cloud = Cloud::aws_like();
    let sim = Simulator::new(SimConfig::deterministic());
    forall("sim_monotone_data", 100, |g| {
        let m = cloud.machine("m5.xlarge").unwrap();
        let n = g.usize_in(2, 12) as u32;
        let gb1 = g.f64_in(10.0, 19.0);
        let gb2 = gb1 + g.f64_in(0.5, 10.0);
        let mut rng1 = c3o::util::rng::Pcg32::new(1);
        let mut rng2 = c3o::util::rng::Pcg32::new(1);
        let t1 = sim.run(m, n, &JobSpec::sort(gb1).stages(), &mut rng1).runtime_s;
        let t2 = sim.run(m, n, &JobSpec::sort(gb2).stages(), &mut rng2).runtime_s;
        assert!(t2 > t1, "more data must take longer: {gb1}GB {t1}s vs {gb2}GB {t2}s");
    });
}

#[test]
fn simulator_never_negative_or_nan() {
    let cloud = Cloud::aws_like();
    let sim = Simulator::new(SimConfig::default());
    forall("sim_finite", 150, |g| {
        let machines = ["c5.large", "m5.xlarge", "r5.2xlarge"];
        let m = cloud.machine(machines[g.usize_in(0, 2)]).unwrap();
        let n = g.usize_in(1, 16) as u32;
        let spec = match g.usize_in(0, 4) {
            0 => JobSpec::sort(g.f64_in(1.0, 40.0)),
            1 => JobSpec::grep(g.f64_in(1.0, 40.0), g.f64_in(0.0, 1.0)),
            2 => JobSpec::sgd(g.f64_in(1.0, 40.0), g.usize_in(1, 100) as u32),
            3 => JobSpec::kmeans(g.f64_in(1.0, 40.0), g.usize_in(2, 12) as u32, 0.001),
            _ => JobSpec::pagerank(g.f64_in(50.0, 500.0), 10f64.powf(-g.f64_in(1.0, 4.0))),
        };
        let mut rng = c3o::util::rng::Pcg32::new(g.case as u64);
        let r = sim.run(m, n, &spec.stages(), &mut rng);
        assert!(r.runtime_s.is_finite() && r.runtime_s > 0.0);
        for s in &r.stages {
            assert!(s.seconds.is_finite() && s.seconds >= 0.0);
            assert!(s.spilled_mb >= 0.0);
        }
    });
}

// --------------------------------------------------------------------------
// Model invariants
// --------------------------------------------------------------------------

#[test]
fn batched_predict_is_bitwise_equal_to_sequential() {
    // The configurator's batched scoring (one featurized matrix, one
    // predict call) must be a pure optimization: for every trained model,
    // predictions over a candidate batch are BITWISE equal to predicting
    // each candidate sequentially.
    let cloud = Cloud::aws_like();
    forall("batched_equals_sequential", 20, |g| {
        let kind = *g.pick(&JobKind::all());
        let mut repo = RuntimeDataRepo::new(kind);
        for _ in 0..g.usize_in(12, 40) {
            let _ = repo.contribute(random_record(g, kind));
        }
        if repo.is_empty() {
            return;
        }
        // few training steps: the property holds at any parameter values
        let mut engine = NativeEngine {
            opt_cfg: c3o::models::OptTrainConfig {
                max_steps: 50,
                ..Default::default()
            },
            ..NativeEngine::default()
        };
        let model_kind = if g.bool() {
            ModelKind::Pessimistic
        } else {
            ModelKind::Optimistic
        };
        let model = engine.train(&cloud, &repo, model_kind).unwrap();

        let nf = kind.feature_names().len();
        let features: Vec<f64> = (0..nf).map(|_| g.f64_in(0.5, 30.0)).collect();
        let machines = ["c5.xlarge", "m5.xlarge", "r5.xlarge"];
        let candidates: Vec<(String, u32)> = machines
            .iter()
            .flat_map(|m| (2u32..=12).map(move |n| (m.to_string(), n)))
            .collect();
        let batch = QueryBatch::from_candidates(&cloud, &candidates, &features);
        let batched = engine.predict_batch(&model, &cloud, &batch).unwrap();
        let sequential = engine.predict(&model, &cloud, &batch.queries()).unwrap();
        assert_eq!(batched.len(), sequential.len());
        for (i, (a, b)) in batched.iter().zip(&sequential).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{model_kind:?} candidate {i}: batched {a} != sequential {b}"
            );
        }
    });
}

// --------------------------------------------------------------------------
// Compute-pool invariants: parallel hot loops must not change a single bit
// --------------------------------------------------------------------------

#[test]
fn pooled_selection_is_bitwise_equal_to_serial_on_native_engine() {
    // The CV fan of `select_and_train_pooled` collects fold results in
    // fixed (kind, fold) order and reduces exactly as the serial loop
    // does, so fold MAPEs, their means, the selected winner, and the
    // winner's trained parameters must all be BITWISE equal to serial
    // execution — at every pool width.
    use c3o::compute::ComputePool;
    use c3o::models::selection::{select_and_train, select_and_train_pooled};
    let cloud = Cloud::aws_like();
    forall("pooled_selection_bitwise", 10, |g| {
        let kind = *g.pick(&JobKind::all());
        let mut repo = RuntimeDataRepo::new(kind);
        for _ in 0..g.usize_in(12, 40) {
            let _ = repo.contribute(random_record(g, kind));
        }
        if repo.len() < 6 {
            return;
        }
        let folds = g.usize_in(2, 4);
        let seed = g.rng().next_u64();
        let mk_engine = || NativeEngine {
            opt_cfg: c3o::models::OptTrainConfig {
                max_steps: 50,
                ..Default::default()
            },
            ..NativeEngine::default()
        };
        let mut serial_engine = mk_engine();
        let (serial_model, serial_report) =
            select_and_train(&mut serial_engine, &cloud, &repo, folds, seed).unwrap();

        // probe batch: compares the trained winners bitwise through
        // their predictions
        let nf = kind.feature_names().len();
        let features: Vec<f64> = (0..nf).map(|_| g.f64_in(0.5, 30.0)).collect();
        let candidates: Vec<(String, u32)> = ["c5.xlarge", "m5.xlarge", "r5.xlarge"]
            .iter()
            .flat_map(|m| (2u32..=12).map(move |n| (m.to_string(), n)))
            .collect();
        let batch = QueryBatch::from_candidates(&cloud, &candidates, &features);
        let serial_preds = serial_engine
            .predict_batch(&serial_model, &cloud, &batch)
            .unwrap();

        for width in [1usize, 2, 8] {
            let pool = ComputePool::new(width);
            let mut engine = mk_engine();
            let (model, report) = select_and_train_pooled(
                &mut engine,
                &cloud,
                &repo,
                folds,
                seed,
                None,
                Some(&pool),
            )
            .unwrap();
            assert_eq!(report.chosen, serial_report.chosen, "width {width}");
            assert_eq!(report.cv_mape.len(), serial_report.cv_mape.len());
            for ((ka, ma), (kb, mb)) in report.cv_mape.iter().zip(&serial_report.cv_mape) {
                assert_eq!(ka, kb, "width {width}: kind order must match serial");
                assert_eq!(
                    ma.to_bits(),
                    mb.to_bits(),
                    "width {width} {ka:?}: pooled CV MAPE {ma} != serial {mb}"
                );
            }
            let preds = engine.predict_batch(&model, &cloud, &batch).unwrap();
            assert_eq!(preds.len(), serial_preds.len());
            for (i, (a, b)) in preds.iter().zip(&serial_preds).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "width {width} probe row {i}: pooled winner {a} != serial {b}"
                );
            }
        }
    });
}

#[test]
fn pooled_selection_is_bitwise_equal_to_serial_on_pjrt_backend() {
    // PJRT predictors are thread-pinned (no native fork), so handing
    // them a pool must degrade to the serial loop — and the outcome
    // must stay bit-identical, with zero pool wait.
    use c3o::compute::ComputePool;
    use c3o::models::selection::{select_and_train, select_and_train_pooled};
    use c3o::models::Predictor;
    use c3o::runtime::Runtime;
    let dir = Runtime::default_dir();
    if !Runtime::artifacts_available(&dir) {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let cloud = Cloud::aws_like();
    let mut serial = Predictor::new(&dir).unwrap();
    let mut pooled = Predictor::new(&dir).unwrap();
    forall("pooled_selection_bitwise_pjrt", 4, |g| {
        let kind = JobKind::Sort;
        let mut repo = RuntimeDataRepo::new(kind);
        for _ in 0..g.usize_in(12, 24) {
            let _ = repo.contribute(random_record(g, kind));
        }
        if repo.len() < 6 {
            return;
        }
        let seed = g.rng().next_u64();
        let (_, rs) = select_and_train(&mut serial, &cloud, &repo, 3, seed).unwrap();
        let pool = ComputePool::new(8);
        let (_, rp) =
            select_and_train_pooled(&mut pooled, &cloud, &repo, 3, seed, None, Some(&pool))
                .unwrap();
        assert_eq!(rp.chosen, rs.chosen);
        assert_eq!(rp.pool_wait_nanos, 0, "PJRT selection must not fan");
        for ((ka, ma), (kb, mb)) in rp.cv_mape.iter().zip(&rs.cv_mape) {
            assert_eq!(ka, kb);
            assert_eq!(ma.to_bits(), mb.to_bits(), "{ka:?}: {ma} != {mb}");
        }
    });
}

#[test]
fn chunked_predict_is_bitwise_equal_to_serial_across_widths() {
    // Row-chunked batch scoring reassembles chunks in row order and
    // scores each row with the same pure function the serial loop uses,
    // so a pool of any width must not change a single output bit.
    use c3o::compute::ComputePool;
    use c3o::models::native::PARALLEL_PREDICT_MIN_ROWS;
    use std::sync::Arc;
    let cloud = Cloud::aws_like();
    forall("chunked_predict_bitwise", 12, |g| {
        let kind = *g.pick(&JobKind::all());
        let mut repo = RuntimeDataRepo::new(kind);
        for _ in 0..g.usize_in(12, 40) {
            let _ = repo.contribute(random_record(g, kind));
        }
        if repo.is_empty() {
            return;
        }
        let mut engine = NativeEngine {
            opt_cfg: c3o::models::OptTrainConfig {
                max_steps: 50,
                ..Default::default()
            },
            ..NativeEngine::default()
        };
        let model_kind = if g.bool() {
            ModelKind::Pessimistic
        } else {
            ModelKind::Optimistic
        };
        let model = engine.train(&cloud, &repo, model_kind).unwrap();

        let nf = kind.feature_names().len();
        let features: Vec<f64> = (0..nf).map(|_| g.f64_in(0.5, 30.0)).collect();
        // wide scaleout range so the batch clears the chunking threshold
        let candidates: Vec<(String, u32)> = ["c5.xlarge", "m5.xlarge", "r5.xlarge"]
            .iter()
            .flat_map(|m| (2u32..=32).map(move |n| (m.to_string(), n)))
            .collect();
        assert!(candidates.len() >= PARALLEL_PREDICT_MIN_ROWS);
        let batch = QueryBatch::from_candidates(&cloud, &candidates, &features);
        let serial = engine.predict_batch(&model, &cloud, &batch).unwrap();
        for width in [1usize, 2, 8] {
            let mut with_pool = engine.clone();
            with_pool.set_compute_pool(Arc::new(ComputePool::new(width)));
            let out = with_pool.predict_batch(&model, &cloud, &batch).unwrap();
            assert_eq!(out.len(), serial.len());
            for (i, (a, b)) in out.iter().zip(&serial).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{model_kind:?} width {width} row {i}: chunked {a} != serial {b}"
                );
            }
        }
    });
}

// --------------------------------------------------------------------------
// Configurator invariants
// --------------------------------------------------------------------------

#[test]
fn configurator_choice_is_optimal_under_policy() {
    let cloud = Cloud::aws_like();
    forall("configurator_policy", 40, |g| {
        let configurator = Configurator::new(&cloud);
        let mut oracle = SimOracle::deterministic(JobKind::Sort, g.case as u64);
        let target = g.f64_log(30.0, 3000.0);
        let req = JobRequest::sort(g.f64_in(10.0, 20.0)).with_target_seconds(target);
        let choice = configurator.configure(&mut oracle, &req).unwrap().unwrap();
        if choice.meets_target {
            // no feasible candidate may be cheaper
            for c in choice.candidates.iter().filter(|c| c.meets_target) {
                assert!(
                    choice.expected_cost_usd <= c.predicted_cost_usd + 1e-9,
                    "cheaper feasible candidate exists"
                );
            }
        } else {
            // infeasible target → fastest candidate chosen
            let fastest = choice
                .candidates
                .iter()
                .map(|c| c.predicted_runtime_s)
                .fold(f64::INFINITY, f64::min);
            assert!((choice.predicted_runtime_s - fastest).abs() < 1e-9);
        }
    });
}

#[test]
fn loosening_target_never_increases_cost() {
    let cloud = Cloud::aws_like();
    forall("target_monotone", 25, |g| {
        let configurator = Configurator::new(&cloud);
        let mut oracle = SimOracle::deterministic(JobKind::Grep, 7);
        let gb = g.f64_in(10.0, 20.0);
        let t1 = g.f64_log(60.0, 1000.0);
        let t2 = t1 * g.f64_in(1.1, 4.0);
        let c1 = configurator
            .configure(&mut oracle, &JobRequest::grep(gb, 0.1).with_target_seconds(t1))
            .unwrap()
            .unwrap();
        let c2 = configurator
            .configure(&mut oracle, &JobRequest::grep(gb, 0.1).with_target_seconds(t2))
            .unwrap()
            .unwrap();
        if c1.meets_target && c2.meets_target {
            assert!(
                c2.expected_cost_usd <= c1.expected_cost_usd + 1e-9,
                "looser target {t2:.0}s costs {} > tighter {t1:.0}s {}",
                c2.expected_cost_usd,
                c1.expected_cost_usd
            );
        }
    });
}

// --------------------------------------------------------------------------
// Feature round-trip & oracle invariants
// --------------------------------------------------------------------------

#[test]
fn job_features_round_trip_through_oracle() {
    forall("feature_round_trip", 200, |g| {
        let spec = match g.usize_in(0, 4) {
            0 => JobSpec::sort(g.f64_in(1.0, 50.0)),
            1 => JobSpec::grep(g.f64_in(1.0, 50.0), g.f64_in(0.0, 1.0)),
            2 => JobSpec::sgd(g.f64_in(1.0, 50.0), g.usize_in(1, 100) as u32),
            3 => JobSpec::kmeans(g.f64_in(1.0, 50.0), g.usize_in(2, 15) as u32, 0.001),
            _ => JobSpec::pagerank(g.f64_in(50.0, 500.0), 10f64.powf(-g.f64_in(1.0, 4.0))),
        };
        let back = SimOracle::spec_from_features(spec.kind(), &spec.job_features()).unwrap();
        // compare feature vectors (covers the -log10 convergence encode)
        let fa = spec.job_features();
        let fb = back.job_features();
        for (a, b) in fa.iter().zip(&fb) {
            assert!((a - b).abs() < 1e-9 * a.abs().max(1.0), "{spec:?} vs {back:?}");
        }
    });
}

#[test]
fn oracle_predictions_consistent_with_direct_simulation() {
    let cloud = Cloud::aws_like();
    forall("oracle_consistency", 50, |g| {
        let mut oracle = SimOracle::deterministic(JobKind::Sort, 5);
        let q = ConfigQuery {
            machine: "m5.xlarge".into(),
            scaleout: g.usize_in(2, 12) as u32,
            job_features: vec![g.f64_in(10.0, 20.0)],
        };
        let a = oracle.predict(&cloud, std::slice::from_ref(&q)).unwrap()[0];
        let b = oracle.predict(&cloud, std::slice::from_ref(&q)).unwrap()[0];
        assert_eq!(a, b, "deterministic oracle must be reproducible");
    });
}

// --------------------------------------------------------------------------
// Stats invariants
// --------------------------------------------------------------------------

#[test]
fn mape_is_zero_iff_exact() {
    forall("mape_zero", 200, |g| {
        let xs = g.vec_f64(1, 40, 1.0, 1e4);
        assert!(stats::mape(&xs, &xs).abs() < 1e-12);
        let mut ys = xs.clone();
        let i = g.usize_in(0, ys.len() - 1);
        ys[i] *= 1.5;
        assert!(stats::mape(&ys, &xs) > 0.0);
    });
}

#[test]
fn median_is_order_invariant_and_bounded() {
    forall("median_props", 200, |g| {
        let xs = g.vec_f64(1, 50, -1e6, 1e6);
        let m = stats::median(&xs);
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(m >= lo && m <= hi);
        let mut shuffled = xs.clone();
        g.rng().shuffle(&mut shuffled);
        assert_eq!(stats::median(&shuffled), m);
    });
}

// --------------------------------------------------------------------------
// Protocol invariants: the read/write split must not change decisions
// --------------------------------------------------------------------------

#[test]
fn recommend_then_contribute_is_decision_equal_to_submit() {
    // The API's core promise: `Recommend` (read) followed by
    // `Contribute` of the observed run is decision-bitwise-equal to one
    // `Submit` (write) — on the request itself AND on the next request,
    // whose model state depends on what the first one contributed.
    use c3o::coordinator::{Coordinator, Organization};
    use c3o::models::Engine;

    let cloud = Cloud::aws_like();
    let corpus = c3o::workloads::ExperimentGrid {
        experiments: c3o::workloads::ExperimentGrid::paper_table1()
            .experiments
            .into_iter()
            .filter(|e| e.spec.kind() == JobKind::Sort)
            .collect(),
        repetitions: 1,
    }
    .execute(&cloud, 3)
    .repo_for(JobKind::Sort);

    forall("recommend_contribute_equals_submit", 6, |g| {
        let seed = g.rng().next_u64();
        let mut via_submit = Coordinator::with_engine(cloud.clone(), Engine::native(), seed);
        let mut via_read = Coordinator::with_engine(cloud.clone(), Engine::native(), seed);
        via_submit.share(&corpus).unwrap();
        via_read.share(&corpus).unwrap();
        let org = Organization::new("prop-org");

        let mut request = JobRequest::sort(g.f64_in(9.0, 21.0));
        if g.bool() {
            request = request.with_target_seconds(g.f64_log(100.0, 3000.0));
        }

        // path A: one write
        let outcome = via_submit.submit(&org, &request).unwrap();
        let submit_choice = outcome.choice.as_ref().expect("model-served");

        // path B: read, then contribute the observed run
        let rec = via_read.recommend(&request).unwrap();
        assert_eq!(rec.choice.machine_type, submit_choice.machine_type);
        assert_eq!(rec.choice.node_count, submit_choice.node_count);
        assert_eq!(
            rec.choice.predicted_runtime_s.to_bits(),
            submit_choice.predicted_runtime_s.to_bits(),
            "read decision must equal the write's decision bitwise"
        );
        assert_eq!(
            rec.choice.expected_cost_usd.to_bits(),
            submit_choice.expected_cost_usd.to_bits()
        );
        via_read
            .contribute(RuntimeRecord {
                job: JobKind::Sort,
                org: org.name.clone(),
                machine: outcome.machine.clone(),
                scaleout: outcome.scaleout,
                job_features: request.spec.job_features(),
                runtime_s: outcome.actual_runtime_s,
            })
            .unwrap();

        // both paths left the same repository behind
        assert_eq!(
            via_read.generation(JobKind::Sort),
            via_submit.generation(JobKind::Sort)
        );

        // ...so the NEXT decision must also be bitwise-identical
        let mut follow_up = JobRequest::sort(g.f64_in(9.0, 21.0));
        if g.bool() {
            follow_up = follow_up.with_target_seconds(g.f64_log(100.0, 3000.0));
        }
        let next_submit = via_submit.recommend(&follow_up).unwrap();
        let next_read = via_read.recommend(&follow_up).unwrap();
        assert_eq!(next_submit.choice.machine_type, next_read.choice.machine_type);
        assert_eq!(next_submit.choice.node_count, next_read.choice.node_count);
        assert_eq!(
            next_submit.choice.predicted_runtime_s.to_bits(),
            next_read.choice.predicted_runtime_s.to_bits(),
            "post-contribution decisions must stay bitwise-equal"
        );
        assert_eq!(next_submit.generation, next_read.generation);
        assert_eq!(
            next_submit.trained_at_generation,
            next_read.trained_at_generation
        );
    });
}
