//! Federation tests: convergence of the peer delta-sync protocol and
//! durability of the segment store — the acceptance gates of the
//! persistence + federation subsystem.
//!
//! * Property: any interleaving of `SyncPull`/`SyncPush` exchanges
//!   between N peers converges to identical generation + identical
//!   canonical records (disjoint corpora), and to identical content
//!   with surfaced conflicts when peers disagree on measurements.
//! * Crash recovery: a store killed mid-append reopens with no loss of
//!   complete records and no duplication.
//! * Acceptance: two durable services fed disjoint org corpora
//!   converge to bitwise-identical repositories serving
//!   bitwise-identical `Recommend` decisions, and a restarted service
//!   recovers its corpus and pre-restart generation from the store.
//! * Mesh federation: roster-scheduled gossip rounds converge N peers
//!   bitwise **with acked-floor op-log truncation active**, the op log
//!   retains only the unacked suffix, a floored durable store
//!   cold-reopens bitwise, and peers below the floor (late v3 joiners)
//!   or outside it (legacy v2 deployments) still converge — via
//!   whole-org snapshot fallback and the compat adapter respectively.

use c3o::api::{ApiError, Client, MeshHello, MeshPeer};
use c3o::cloud::Cloud;
use c3o::configurator::JobRequest;
use c3o::coordinator::{Coordinator, CoordinatorService, ServiceConfig};
use c3o::models::Engine;
use c3o::repo::{RuntimeDataRepo, RuntimeRecord};
use c3o::store::{
    mesh_peer, mesh_round, sync, JobStore, StoreOp, SyncOptions, SyncProtocol, SyncScope,
    SyncStats,
};
use c3o::util::prop::{forall, Gen};
use c3o::workloads::{ExperimentGrid, JobKind};
use std::path::{Path, PathBuf};
use std::time::Duration;

const MACHINES: [&str; 3] = ["c5.xlarge", "m5.xlarge", "r5.xlarge"];

/// One-job v3 exchange through the consolidated [`sync`] entry point.
fn sync_job(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    job: JobKind,
) -> Result<SyncStats, ApiError> {
    sync(
        local,
        peer,
        &SyncOptions {
            scope: SyncScope::Job(job),
            ..SyncOptions::default()
        },
    )
    .map(|summary| summary.stats)
}

/// One-job exchange over the legacy v2 org-granular protocol.
fn sync_job_v2(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    job: JobKind,
) -> Result<SyncStats, ApiError> {
    sync(
        local,
        peer,
        &SyncOptions {
            scope: SyncScope::Job(job),
            protocol: SyncProtocol::V2,
            ..SyncOptions::default()
        },
    )
    .map(|summary| summary.stats)
}

/// Multi-job v3 exchange, stats folded.
fn sync_all(
    local: &mut dyn Client,
    peer: &mut dyn Client,
    jobs: &[JobKind],
) -> Result<SyncStats, ApiError> {
    sync(
        local,
        peer,
        &SyncOptions {
            scope: SyncScope::Jobs(jobs.to_vec()),
            ..SyncOptions::default()
        },
    )
    .map(|summary| summary.stats)
}

fn temp_root(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("c3o_fed_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A no-training peer (cold-start threshold maxed): the properties
/// exercise the exchange, not model selection.
fn peer(cloud: &Cloud, seed: u64) -> Coordinator {
    let mut coord = Coordinator::with_engine(cloud.clone(), Engine::native(), seed);
    coord.min_records = usize::MAX;
    coord
}

/// Sweep the peer chain (0,1), (1,2), ... until a full sweep moves no
/// records; panics if `max_sweeps` is not enough.
fn sync_until_quiescent(peers: &mut [Coordinator], job: JobKind, max_sweeps: usize) -> SyncStats {
    let mut total = SyncStats::default();
    for _ in 0..max_sweeps {
        let mut sweep = SyncStats::default();
        for i in 0..peers.len() - 1 {
            let (left, right) = peers.split_at_mut(i + 1);
            let stats = sync_job(&mut left[i], &mut right[0], job).unwrap();
            sweep.fold(&stats);
        }
        total.fold(&sweep);
        if sweep.quiescent() {
            return total;
        }
    }
    panic!("no quiescence after {max_sweeps} sweeps: {total:?}");
}

#[test]
fn gossip_converges_to_identical_generation_and_records() {
    let cloud = Cloud::aws_like();
    forall("gossip_convergence", 25, |g| {
        let n_peers = g.usize_in(2, 4);
        let mut peers: Vec<Coordinator> = (0..n_peers)
            .map(|i| peer(&cloud, 100 + i as u64))
            .collect();
        // disjoint corpora: each peer's configurations are unique to it
        // (the data-gb feature embeds the peer index)
        let mut total_records = 0usize;
        for (i, p) in peers.iter_mut().enumerate() {
            let count = g.usize_in(1, 20);
            total_records += count;
            let records: Vec<RuntimeRecord> = (0..count)
                .map(|k| RuntimeRecord {
                    job: JobKind::Sort,
                    org: format!("org-{i}"),
                    machine: MACHINES[g.usize_in(0, 2)].to_string(),
                    scaleout: g.usize_in(2, 12) as u32,
                    job_features: vec![(i * 10_000 + k) as f64 + 0.5],
                    runtime_s: g.f64_log(10.0, 5000.0),
                })
                .collect();
            p.share(&RuntimeDataRepo::from_records(JobKind::Sort, records))
                .unwrap();
        }

        // a burst of random exchanges in arbitrary order...
        for _ in 0..g.usize_in(0, 6) {
            let i = g.usize_in(0, n_peers - 1);
            let j = g.usize_in(0, n_peers - 1);
            if i == j {
                continue;
            }
            let (lo, hi) = (i.min(j), i.max(j));
            let (left, right) = peers.split_at_mut(hi);
            sync_job(&mut left[lo], &mut right[0], JobKind::Sort).unwrap();
        }
        // ...then sweeps until quiescent
        sync_until_quiescent(&mut peers, JobKind::Sort, 20);

        let reference = peers[0].repo(JobKind::Sort).unwrap();
        let ref_records = reference.canonical_records();
        assert_eq!(ref_records.len(), total_records, "disjoint corpora only add");
        for p in &peers[1..] {
            let repo = p.repo(JobKind::Sort).unwrap();
            assert_eq!(
                p.generation(JobKind::Sort),
                peers[0].generation(JobKind::Sort),
                "generations converge"
            );
            assert_eq!(
                repo.canonical_records(),
                ref_records,
                "record sets converge"
            );
            assert_eq!(repo.content_digest(), reference.content_digest());
            assert_eq!(repo.watermarks(), reference.watermarks());
        }
    });
}

#[test]
fn repo_rebuilt_from_sync_ops_yields_identical_cached_feature_matrix() {
    // Federation path of the incremental feature cache: a peer that
    // rebuilds the corpus purely from sync ops (full op-log pull into an
    // empty repo, then the canonical reorder) must end up with cached
    // training inputs bitwise-identical to the directly-contributing
    // origin's. Converged peers already hold bitwise-identical records;
    // this extends that guarantee to the feature matrices derived from
    // them — so converged peers train bitwise-identical models through
    // the cached path too.
    use c3o::repo::{FeatureMatrixCache, Featurizer};
    let cloud = Cloud::aws_like();
    let featurizer = Featurizer::new(&cloud);

    let mut origin = RuntimeDataRepo::new(JobKind::Sort);
    let mut origin_cache = FeatureMatrixCache::new();
    for k in 0..36usize {
        origin
            .contribute(RuntimeRecord {
                job: JobKind::Sort,
                org: format!("org-{}", k % 3),
                machine: MACHINES[k % 3].to_string(),
                scaleout: 2 + (k % 7) as u32,
                job_features: vec![10.0 + k as f64 * 0.25],
                runtime_s: 100.0 + ((k * k) % 97) as f64,
            })
            .unwrap();
        // keep the origin's cache warm incrementally (delta replays),
        // never one bulk rebuild at the end
        if k % 5 == 0 {
            origin_cache.refresh(&featurizer, &origin);
        }
    }

    // the mirror rebuilds purely from the op-log delta
    let mut mirror = RuntimeDataRepo::new(JobKind::Sort);
    let mut mirror_cache = FeatureMatrixCache::new();
    let ops = origin.delta_for(&mirror.watermarks());
    assert_eq!(ops.len(), origin.len());
    mirror.apply_sync_ops(&ops).unwrap();
    mirror.canonicalize();
    origin.canonicalize();
    origin_cache.refresh(&featurizer, &origin);
    mirror_cache.refresh(&featurizer, &mirror);

    // records converged bitwise...
    assert_eq!(origin.content_digest(), mirror.content_digest());
    // ...and so did the cached training inputs, which also match a
    // from-scratch featurization of the converged corpus
    let (o_space, o_x, o_y) = origin_cache.fit(&origin);
    let (m_space, m_x, m_y) = mirror_cache.fit(&mirror);
    let (s_space, s_x, s_y) = featurizer.fit(&origin);
    for (space, x, y) in [(&m_space, &m_x, &m_y), (&s_space, &s_x, &s_y)] {
        assert_eq!(o_space.names, space.names);
        assert_eq!((o_x.rows, o_x.cols), (x.rows, x.cols));
        for (a, b) in o_space.mean.iter().zip(&space.mean) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in o_space.sd.iter().zip(&space.sd) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(o_space.y_mean.to_bits(), space.y_mean.to_bits());
        assert_eq!(o_space.y_sd.to_bits(), space.y_sd.to_bits());
        for (a, b) in o_x.data.iter().zip(&x.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in o_y.iter().zip(y.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}

#[test]
fn conflicting_measurements_converge_to_one_deterministic_winner() {
    let cloud = Cloud::aws_like();
    forall("conflict_convergence", 25, |g| {
        let n_peers = g.usize_in(2, 3);
        // every peer measures the SAME configuration grid with its own
        // runtimes: every shared key is a potential conflict
        let n_configs = g.usize_in(1, 10);
        let configs: Vec<(String, u32, f64)> = (0..n_configs)
            .map(|k| {
                (
                    MACHINES[g.usize_in(0, 2)].to_string(),
                    g.usize_in(2, 12) as u32,
                    k as f64 + 0.5,
                )
            })
            .collect();
        let mut all_records: Vec<RuntimeRecord> = Vec::new();
        let mut peers: Vec<Coordinator> = Vec::new();
        for i in 0..n_peers {
            let records: Vec<RuntimeRecord> = configs
                .iter()
                .map(|(machine, scaleout, gb)| RuntimeRecord {
                    job: JobKind::Sort,
                    org: format!("org-{i}"),
                    machine: machine.clone(),
                    scaleout: *scaleout,
                    job_features: vec![*gb],
                    runtime_s: g.f64_log(10.0, 5000.0),
                })
                .collect();
            all_records.extend(records.iter().cloned());
            let mut p = peer(&cloud, 200 + i as u64);
            p.share(&RuntimeDataRepo::from_records(JobKind::Sort, records))
                .unwrap();
            peers.push(p);
        }
        let stats = sync_until_quiescent(&mut peers, JobKind::Sort, 30);

        // content converges (generation may legitimately differ when
        // replacements happened on some peers but not others)
        let ref_records = peers[0].repo(JobKind::Sort).unwrap().canonical_records();
        for p in &peers[1..] {
            assert_eq!(
                p.repo(JobKind::Sort).unwrap().canonical_records(),
                ref_records
            );
        }
        // every configuration resolved to the globally-smallest
        // (runtime, org) measurement — the deterministic winner
        assert_eq!(ref_records.len(), n_configs);
        for held in &ref_records {
            let winner = all_records
                .iter()
                .filter(|r| r.config_key() == held.config_key())
                .min_by(|a, b| a.merge_priority().cmp(&b.merge_priority()))
                .expect("config came from somewhere");
            assert_eq!(held.org, winner.org);
            assert_eq!(held.runtime_s.to_bits(), winner.runtime_s.to_bits());
        }
        // disagreements were surfaced, not silently dropped (each
        // config was measured by every peer; identical runtimes from
        // the log-uniform generator are vanishingly rare but possible,
        // so only require conflicts when runtimes actually differed)
        let distinct_runtimes = {
            let mut bits: Vec<u64> = all_records.iter().map(|r| r.runtime_s.to_bits()).collect();
            bits.sort_unstable();
            bits.dedup();
            bits.len()
        };
        if n_peers > 1 && distinct_runtimes == all_records.len() {
            assert!(stats.conflicts > 0, "conflicts must be surfaced");
        }
    });
}

#[test]
fn crash_torn_append_recovers_without_loss_or_duplication() {
    let root = temp_root("crash_recovery");
    let (mut store, mut repo) = JobStore::open(&root, JobKind::Sort).unwrap();
    // a blind-contribute history where consecutive pairs re-measure the
    // SAME configuration (the submit path allows duplicates) — recovery
    // must preserve them, not dedup them
    for i in 0..20u32 {
        let record = RuntimeRecord {
            job: JobKind::Sort,
            org: format!("org-{}", i % 3),
            machine: MACHINES[((i / 2) % 3) as usize].to_string(),
            scaleout: 2 + (i / 2) % 6,
            job_features: vec![10.0 + (i / 2) as f64],
            runtime_s: 100.0 + i as f64,
        };
        let seqno = repo.contribute(record.clone()).unwrap();
        store
            .append(&[StoreOp::Contribute { seqno, record }], repo.generation())
            .unwrap();
    }
    let pre_crash = repo.clone();
    drop(store);

    // kill mid-append: torn half-line at the tail of the last segment
    let seg = std::fs::read_dir(root.join("sort"))
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.file_name().unwrap().to_string_lossy().starts_with("wal-"))
        .max()
        .unwrap();
    let mut bytes = std::fs::read(&seg).unwrap();
    bytes.extend_from_slice(b"21,C,sort,org-0,m5.xla");
    std::fs::write(&seg, bytes).unwrap();

    let (_store2, recovered) = JobStore::open(&root, JobKind::Sort).unwrap();
    assert_eq!(recovered.records(), pre_crash.records(), "no loss, no dup");
    assert_eq!(recovered.generation(), pre_crash.generation());

    // reopening again is idempotent
    let (_store3, recovered2) = JobStore::open(&root, JobKind::Sort).unwrap();
    assert_eq!(recovered2.records(), pre_crash.records());
    let _ = std::fs::remove_dir_all(root);
}

#[test]
fn background_sync_driver_converges_two_services() {
    let cloud = Cloud::aws_like();
    let corpus = sort_corpus(&cloud);
    let half = corpus.len() / 2;
    let service_a = CoordinatorService::spawn(
        cloud.clone(),
        ServiceConfig::default()
            .with_workers(2)
            .with_pjrt_workers(0)
            .with_seed(3),
    );
    let service_b = CoordinatorService::spawn(
        cloud,
        ServiceConfig::default()
            .with_workers(2)
            .with_pjrt_workers(0)
            .with_seed(4),
    );
    service_a
        .share(RuntimeDataRepo::from_records(
            JobKind::Sort,
            relabel(&corpus.records()[..half], "org-alpha"),
        ))
        .unwrap();
    service_b
        .share(RuntimeDataRepo::from_records(
            JobKind::Sort,
            relabel(&corpus.records()[half..], "org-beta"),
        ))
        .unwrap();

    // the background gossip loop does the rest
    let driver = service_a.sync_with(
        vec![service_b.client()],
        vec![JobKind::Sort],
        Duration::from_millis(25),
    );
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    let target = corpus.len() as u64;
    while service_a.generation(JobKind::Sort) != target
        || service_b.generation(JobKind::Sort) != target
    {
        assert!(
            std::time::Instant::now() < deadline,
            "sync driver did not converge: generations {}/{} (want {target})",
            service_a.generation(JobKind::Sort),
            service_b.generation(JobKind::Sort),
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    let stats = driver.stop();
    assert_eq!(
        (stats.records_in + stats.records_out) as usize,
        corpus.len(),
        "exactly one full exchange despite repeated rounds: {stats:?}"
    );
    assert_eq!(
        service_a.repo_snapshot(JobKind::Sort).canonical_records(),
        service_b.repo_snapshot(JobKind::Sort).canonical_records()
    );
    service_a.shutdown();
    service_b.shutdown();
}

// ---------------------------------------------------------------------------
// record-level deltas: O(changed) shipping and no re-offered duplicates
// ---------------------------------------------------------------------------

#[test]
fn single_record_contribution_ships_exactly_one_op() {
    // Property: once two peers converge, contributing ONE record ships
    // exactly one op on the next exchange (offered == applied == 1) —
    // even when the corpora contain blind duplicate configurations —
    // and the round after that re-offers nothing.
    let cloud = Cloud::aws_like();
    forall("single_record_delta", 25, |g| {
        let mut peers: Vec<Coordinator> = vec![peer(&cloud, 300), peer(&cloud, 301)];
        for i in 0..peers.len() {
            let count = g.usize_in(1, 15);
            let mut records: Vec<RuntimeRecord> = (0..count)
                .map(|k| RuntimeRecord {
                    job: JobKind::Sort,
                    org: format!("org-{i}"),
                    machine: MACHINES[g.usize_in(0, 2)].to_string(),
                    scaleout: g.usize_in(2, 12) as u32,
                    job_features: vec![(i * 10_000 + k) as f64 + 0.5],
                    runtime_s: g.f64_log(10.0, 5000.0),
                })
                .collect();
            if g.bool() {
                // submit-style blind duplicate: same config, new runtime
                let mut dup = records[g.usize_in(0, count - 1)].clone();
                dup.runtime_s += 1.0;
                records.push(dup);
            }
            // the contribute path keeps duplicates (share would dedup)
            for r in records {
                peers[i].contribute(r).unwrap();
            }
        }
        sync_until_quiescent(&mut peers, JobKind::Sort, 10);

        // converged peers — blind duplicates included — offer NOTHING
        let (left, right) = peers.split_at_mut(1);
        let idle = sync_job(&mut left[0], &mut right[0], JobKind::Sort).unwrap();
        assert!(idle.quiescent());
        assert_eq!(idle.offered, 0, "converged logs re-offer nothing: {idle:?}");

        // one new record: the next exchange ships exactly one op
        left[0]
            .contribute(RuntimeRecord {
                job: JobKind::Sort,
                org: "org-0".into(),
                machine: MACHINES[0].to_string(),
                scaleout: 3,
                job_features: vec![999_999.5],
                runtime_s: 321.0,
            })
            .unwrap();
        let stats = sync_job(&mut left[0], &mut right[0], JobKind::Sort).unwrap();
        assert_eq!(stats.offered, 1, "exactly the changed record ships");
        assert_eq!(stats.records_in + stats.records_out, 1);
        assert_eq!(stats.skipped, 0);

        // and the round after that is silent again
        let after = sync_job(&mut left[0], &mut right[0], JobKind::Sort).unwrap();
        assert!(after.quiescent());
        assert_eq!(after.offered, 0);
    });
}

#[test]
fn blind_duplicates_ship_once_and_are_never_reoffered() {
    // Deterministic contrast of the v3 (record-level) and v2
    // (org-granular) exchanges on the exact ROADMAP pathology: an org
    // holding blind-contributed duplicate configurations a peer's merge
    // never accepts.
    let cloud = Cloud::aws_like();
    let dup_history = |p: &mut Coordinator| {
        // the scaleout-4 config is measured twice, better run first, so
        // the later duplicate LOSES merge resolution at every receiver —
        // the op a v2 peer is re-offered forever
        for (scaleout, runtime) in [(4u32, 90.0), (4, 100.0), (8, 60.0)] {
            p.contribute(RuntimeRecord {
                job: JobKind::Sort,
                org: "dup-org".into(),
                machine: "m5.xlarge".into(),
                scaleout,
                job_features: vec![10.0],
                runtime_s: runtime,
            })
            .unwrap();
        }
    };

    // v3: the duplicate ships once (seen), then never again
    let mut a = peer(&cloud, 310);
    let mut b = peer(&cloud, 311);
    dup_history(&mut a);
    let first = sync_job(&mut a, &mut b, JobKind::Sort).unwrap();
    assert_eq!(first.offered, 3, "the whole history ships once");
    assert_eq!(first.records_in + first.records_out, 2, "dedup keeps 2");
    assert_eq!(first.skipped, 1, "the losing duplicate is seen, not applied");
    let second = sync_job(&mut a, &mut b, JobKind::Sort).unwrap();
    assert!(second.quiescent());
    assert_eq!(second.offered, 0, "nothing is ever re-offered");

    // v2 on an identical pair: the org is re-offered on EVERY exchange
    let mut a2 = peer(&cloud, 312);
    let mut b2 = peer(&cloud, 313);
    dup_history(&mut a2);
    let first = sync_job_v2(&mut a2, &mut b2, JobKind::Sort).unwrap();
    assert_eq!(first.records_in + first.records_out, 2);
    let second = sync_job_v2(&mut a2, &mut b2, JobKind::Sort).unwrap();
    assert!(second.quiescent(), "correct but wasteful");
    assert!(
        second.offered > 0,
        "v2 re-offers the blind-duplicate org forever: {second:?}"
    );

    // the two protocols interoperate: a v3 peer that received data via
    // the v2 path still converges (content-wise) with everyone
    assert_eq!(
        b.repo(JobKind::Sort).unwrap().canonical_records(),
        b2.repo(JobKind::Sort).unwrap().canonical_records()
    );
}

// ---------------------------------------------------------------------------
// store-format migration: PR-3 stores open bitwise under the new code
// ---------------------------------------------------------------------------

/// Copy the committed PR-3-format fixture into a scratch dir (opening a
/// store may later write beside it; the fixture itself must stay
/// pristine).
fn copy_fixture(name: &str) -> PathBuf {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/pr3-store");
    let dst = temp_root(name);
    let mut copied = 0usize;
    for entry in std::fs::read_dir(&src).unwrap() {
        let entry = entry.unwrap();
        if !entry.path().is_dir() {
            continue; // the fixture root also holds a README
        }
        let job_dir = dst.join(entry.file_name());
        std::fs::create_dir_all(&job_dir).unwrap();
        for f in std::fs::read_dir(entry.path()).unwrap() {
            let f = f.unwrap();
            std::fs::copy(f.path(), job_dir.join(f.file_name())).unwrap();
            copied += 1;
        }
    }
    assert!(copied > 0, "fixture copy found no store files at {src:?}");
    dst
}

#[test]
fn pr3_format_store_recovers_bitwise_and_round_trips_sync() {
    let cloud = Cloud::aws_like();
    let root = copy_fixture("pr3_migration");

    // 1) the legacy WAL (8-field lines, no seqnos) recovers bitwise
    let (store, repo) = JobStore::open(&root, JobKind::Sort).unwrap();
    assert_eq!(repo.len(), 4);
    assert_eq!(repo.generation(), 4);
    assert_eq!(store.generation(), 4);
    // canonical order was WAL-logged (the trailing K line) and replays:
    // (config_key, org, runtime) — c5 first, then m5 x2, then m5 x4 dup
    let orgs: Vec<&str> = repo.records().iter().map(|r| r.org.as_str()).collect();
    assert_eq!(orgs, ["org-b", "org-c", "org-a", "org-a"]);
    assert_eq!(repo.records()[2].runtime_s, 90.0, "blind dup order: 90 first");
    assert_eq!(repo.records()[3].runtime_s, 100.0);
    // replay assigned the op-log seqnos the legacy lines lack
    assert_eq!(repo.log_len("org-a"), 2);
    assert_eq!(repo.log_len("org-b"), 1);
    assert_eq!(repo.log_len("org-c"), 1);
    drop(store);

    // 2) reopening is idempotent (bitwise again)
    let (_s2, repo2) = JobStore::open(&root, JobKind::Sort).unwrap();
    assert_eq!(repo2.records(), repo.records());
    assert_eq!(repo2.watermarks(), repo.watermarks());

    // 3) a durable coordinator over the migrated store round-trips one
    //    record-level sync against a fresh peer
    let mut durable = Coordinator::open_with_store(
        cloud.clone(),
        &PathBuf::from("/nonexistent-artifacts"),
        21,
        &root,
    )
    .unwrap();
    let mut fresh = peer(&cloud, 320);
    let stats = sync_job(&mut durable, &mut fresh, JobKind::Sort).unwrap();
    assert_eq!(stats.offered, 4, "the full migrated log ships");
    assert_eq!(
        stats.records_in + stats.records_out,
        3,
        "the losing blind duplicate dedups on apply"
    );
    assert_eq!(stats.skipped, 1, "...logged as seen at the receiver");
    let fresh_repo = fresh.repo(JobKind::Sort).unwrap();
    assert_eq!(fresh_repo.len(), 3, "receiver holds the deduped corpus");
    assert!(
        fresh_repo.records().iter().all(|r| r.runtime_s != 100.0),
        "the losing duplicate measurement is not in the holdings"
    );
    let again = sync_job(&mut durable, &mut fresh, JobKind::Sort).unwrap();
    assert!(again.quiescent());
    assert_eq!(again.offered, 0, "migrated logs are never re-offered");
    let _ = std::fs::remove_dir_all(root);
}

// ---------------------------------------------------------------------------
// acceptance: two durable services converge and survive restart
// ---------------------------------------------------------------------------

fn sort_corpus(cloud: &Cloud) -> RuntimeDataRepo {
    ExperimentGrid {
        experiments: ExperimentGrid::paper_table1()
            .experiments
            .into_iter()
            .filter(|e| e.spec.kind() == JobKind::Sort)
            .collect(),
        repetitions: 1,
    }
    .execute(cloud, 11)
    .repo_for(JobKind::Sort)
}

fn relabel(records: &[RuntimeRecord], org: &str) -> Vec<RuntimeRecord> {
    records.iter().map(|r| r.with_org(org)).collect()
}

#[test]
fn durable_services_converge_and_recover_across_restart() {
    let cloud = Cloud::aws_like();
    let root_a = temp_root("svc_a");
    let root_b = temp_root("svc_b");
    let no_artifacts = PathBuf::from("/nonexistent-artifacts");
    let config_a = ServiceConfig::default()
        .with_workers(2)
        .with_pjrt_workers(0)
        .with_artifacts_dir(no_artifacts.clone())
        .with_seed(7)
        .with_store_dir(root_a.clone());
    let config_b = ServiceConfig::default()
        .with_workers(2)
        .with_pjrt_workers(0)
        .with_artifacts_dir(no_artifacts)
        .with_seed(9)
        .with_store_dir(root_b.clone());

    // two services from empty stores, fed disjoint org corpora
    let corpus = sort_corpus(&cloud);
    let half = corpus.len() / 2;
    let service_a = CoordinatorService::open(cloud.clone(), config_a.clone()).unwrap();
    let service_b = CoordinatorService::open(cloud.clone(), config_b).unwrap();
    service_a
        .share(RuntimeDataRepo::from_records(
            JobKind::Sort,
            relabel(&corpus.records()[..half], "org-alpha"),
        ))
        .unwrap();
    service_b
        .share(RuntimeDataRepo::from_records(
            JobKind::Sort,
            relabel(&corpus.records()[half..], "org-beta"),
        ))
        .unwrap();

    // synced via SyncPull/SyncPush until quiescent
    let mut client_a = service_a.client();
    let mut client_b = service_b.client();
    let stats = sync_all(&mut client_a, &mut client_b, &[JobKind::Sort]).unwrap();
    assert_eq!(
        (stats.records_in + stats.records_out) as usize,
        corpus.len(),
        "full bidirectional exchange"
    );
    let again = sync_all(&mut client_a, &mut client_b, &[JobKind::Sort]).unwrap();
    assert!(again.quiescent(), "second exchange is a no-op");

    // bitwise-identical repository contents (incl. record order: both
    // sides canonicalized on apply)
    let repo_a = service_a.repo_snapshot(JobKind::Sort);
    let repo_b = service_b.repo_snapshot(JobKind::Sort);
    assert_eq!(repo_a.records(), repo_b.records(), "bitwise-identical repos");
    assert_eq!(repo_a.generation(), repo_b.generation());
    assert_eq!(repo_a.len(), corpus.len());

    // identical Recommend decisions, bit for bit
    let request = JobRequest::sort(14.5).with_target_seconds(700.0);
    let rec_a = client_a.recommend(request.clone()).unwrap();
    let rec_b = client_b.recommend(request.clone()).unwrap();
    assert_eq!(rec_a.choice.machine_type, rec_b.choice.machine_type);
    assert_eq!(rec_a.choice.node_count, rec_b.choice.node_count);
    assert_eq!(
        rec_a.choice.predicted_runtime_s.to_bits(),
        rec_b.choice.predicted_runtime_s.to_bits()
    );
    assert_eq!(rec_a.generation, rec_b.generation);
    assert_eq!(rec_a.trained_at_generation, rec_b.trained_at_generation);

    // restart A: the store is the only carrier of its state
    let info_before = client_a.snapshot_info(JobKind::Sort).unwrap();
    let records_before = repo_a.records().to_vec();
    service_a.shutdown();
    service_b.shutdown();

    let service_a2 = CoordinatorService::open(cloud, config_a).unwrap();
    let client_a2 = service_a2.client();
    let info_after = client_a2.snapshot_info(JobKind::Sort).unwrap();
    assert_eq!(
        info_after.generation, info_before.generation,
        "restart answers SnapshotInfo with the pre-restart generation"
    );
    assert_eq!(info_after.records, info_before.records);
    assert_eq!(
        service_a2.repo_snapshot(JobKind::Sort).records(),
        &records_before[..],
        "corpus recovered bitwise"
    );
    // the recovered service serves model reads before any new write —
    // and decides exactly as it did before the restart
    let rec_recovered = client_a2.recommend(request).unwrap();
    assert_eq!(rec_recovered.choice.machine_type, rec_a.choice.machine_type);
    assert_eq!(rec_recovered.choice.node_count, rec_a.choice.node_count);
    assert_eq!(
        rec_recovered.choice.predicted_runtime_s.to_bits(),
        rec_a.choice.predicted_runtime_s.to_bits()
    );
    service_a2.shutdown();
    let _ = std::fs::remove_dir_all(root_a);
    let _ = std::fs::remove_dir_all(root_b);
}

// ---------------------------------------------------------------------------
// mesh federation: roster-scheduled gossip with acked-floor truncation
// ---------------------------------------------------------------------------

fn mesh_names(n: usize) -> Vec<String> {
    (0..n).map(|i| format!("peer-{i}")).collect()
}

/// Introduce every peer to the full roster: one hello per deployment
/// whose `known` list carries everyone (gossip-joined members are live,
/// so fanout targeting works from the first round).
fn mesh_bootstrap(peers: &mut [Coordinator]) {
    let intro: Vec<MeshPeer> = mesh_names(peers.len()).iter().map(|n| mesh_peer(n)).collect();
    for (i, p) in peers.iter_mut().enumerate() {
        p.mesh_hello(MeshHello {
            from: intro[(i + 1) % intro.len()].clone(),
            known: intro.clone(),
            acked: Vec::new(),
        })
        .unwrap();
    }
}

/// One full sweep: every peer runs one [`mesh_round`] against the rest
/// of the roster. Returns (records changed, peer round trips).
fn mesh_sweep(peers: &mut [Coordinator], names: &[String], fanout: usize) -> (u64, u64) {
    let (mut changed, mut trips) = (0u64, 0u64);
    for i in 0..peers.len() {
        let (before, rest) = peers.split_at_mut(i);
        let (local, after) = rest.split_first_mut().unwrap();
        let mut refs: Vec<(String, &mut dyn Client)> = Vec::new();
        for (k, p) in before.iter_mut().enumerate() {
            refs.push((names[k].clone(), p));
        }
        for (k, p) in after.iter_mut().enumerate() {
            refs.push((names[i + 1 + k].clone(), p));
        }
        let report = mesh_round(local, &mut refs, fanout).unwrap();
        changed += report.changed;
        trips += report.peer_round_trips;
    }
    (changed, trips)
}

/// Sweep mesh rounds until every peer's repositories carry identical
/// content digests AND a full sweep changes nothing; then a few extra
/// sweeps so acks finish propagating and every peer's self-tick folds
/// the acked prefix out of its op logs. Panics without convergence.
fn mesh_until_quiescent(
    peers: &mut [Coordinator],
    jobs: &[JobKind],
    fanout: usize,
    max_sweeps: usize,
) {
    let names = mesh_names(peers.len());
    let mut converged = false;
    for _ in 0..max_sweeps {
        let (changed, _) = mesh_sweep(peers, &names, fanout);
        let digests_agree = jobs.iter().all(|&job| {
            let reference = peers[0].repo(job).map(|r| r.content_digest());
            peers[1..]
                .iter()
                .all(|p| p.repo(job).map(|r| r.content_digest()) == reference)
        });
        if changed == 0 && digests_agree {
            converged = true;
            break;
        }
    }
    assert!(converged, "mesh did not converge within {max_sweeps} sweeps");
    // ack propagation needs exchanges; the truncating self-tick needs a
    // later sweep again — rotate through everyone twice, with margin
    for _ in 0..2 * peers.len() + 2 {
        mesh_sweep(peers, &names, fanout);
    }
}

#[test]
fn mesh_rounds_converge_bitwise_with_acked_floor_truncation() {
    let cloud = Cloud::aws_like();
    let corpus = sort_corpus(&cloud);
    let n = 3;
    let mut peers: Vec<Coordinator> = (0..n)
        .map(|i| {
            let mut c = Coordinator::with_engine(cloud.clone(), Engine::native(), 400 + i as u64);
            c.set_mesh_name(&format!("peer-{i}"));
            c
        })
        .collect();
    // disjoint interleaved slices: record r belongs to peer r % n
    let records = corpus.records();
    for (i, p) in peers.iter_mut().enumerate() {
        let slice: Vec<RuntimeRecord> = records
            .iter()
            .enumerate()
            .filter(|(r, _)| r % n == i)
            .map(|(_, rec)| rec.with_org(&format!("org-{i}")))
            .collect();
        p.share(&RuntimeDataRepo::from_records(JobKind::Sort, slice))
            .unwrap();
    }
    mesh_bootstrap(&mut peers);
    mesh_until_quiescent(&mut peers, &[JobKind::Sort], 1, 64);

    // bitwise-identical repositories — with truncation active
    let reference = peers[0].repo(JobKind::Sort).unwrap().clone();
    assert_eq!(reference.len(), records.len(), "disjoint corpora only add");
    for p in &peers[1..] {
        let repo = p.repo(JobKind::Sort).unwrap();
        assert_eq!(repo.canonical_records(), reference.canonical_records());
        assert_eq!(repo.content_digest(), reference.content_digest());
        assert_eq!(repo.watermarks(), reference.watermarks());
    }
    // every live member acked the full history, so every org's floor
    // rose to its top seqno: the op logs hold ONLY the unacked suffix —
    // which is empty. That is the op-log memory bound.
    for p in &peers {
        assert!(p.metrics().ops_truncated > 0, "acked floors truncated");
        let repo = p.repo(JobKind::Sort).unwrap();
        assert_eq!(repo.retained_log_entries(), 0, "only the unacked suffix is retained");
        for (org, mark) in &repo.watermarks() {
            assert_eq!(repo.log_floor(org), mark.seqno, "{org}: floor covers the acked prefix");
        }
    }

    // a fresh write is the one retained entry until the mesh acks it
    peers[0]
        .contribute(RuntimeRecord {
            job: JobKind::Sort,
            org: "org-0".into(),
            machine: MACHINES[0].to_string(),
            scaleout: 5,
            job_features: vec![777_777.5],
            runtime_s: 123.0,
        })
        .unwrap();
    assert_eq!(peers[0].repo(JobKind::Sort).unwrap().retained_log_entries(), 1);
    mesh_until_quiescent(&mut peers, &[JobKind::Sort], 1, 32);
    for p in &peers {
        assert_eq!(p.repo(JobKind::Sort).unwrap().retained_log_entries(), 0);
    }

    // decisions over the converged (and truncated) corpora are bitwise
    // identical across the mesh
    let request = JobRequest::sort(14.5).with_target_seconds(700.0);
    let mut choices = Vec::new();
    for p in peers.iter_mut() {
        choices.push(p.recommend(&request).unwrap());
    }
    for rec in &choices[1..] {
        assert_eq!(rec.choice.machine_type, choices[0].choice.machine_type);
        assert_eq!(rec.choice.node_count, choices[0].choice.node_count);
        assert_eq!(
            rec.choice.predicted_runtime_s.to_bits(),
            choices[0].choice.predicted_runtime_s.to_bits()
        );
        assert_eq!(rec.generation, choices[0].generation);
    }
}

#[test]
fn floored_durable_store_cold_reopens_bitwise() {
    let cloud = Cloud::aws_like();
    let root_a = temp_root("mesh_floor_a");
    let root_b = temp_root("mesh_floor_b");
    let no_artifacts = PathBuf::from("/nonexistent-artifacts");
    let mut peers: Vec<Coordinator> = (0..2)
        .map(|i| {
            let root = if i == 0 { &root_a } else { &root_b };
            let mut c =
                Coordinator::open_with_store(cloud.clone(), &no_artifacts, 31 + i as u64, root)
                    .unwrap();
            c.min_records = usize::MAX;
            c.set_mesh_name(&format!("peer-{i}"));
            c
        })
        .collect();
    for (i, p) in peers.iter_mut().enumerate() {
        let records: Vec<RuntimeRecord> = (0..8usize)
            .map(|k| RuntimeRecord {
                job: JobKind::Sort,
                org: format!("org-{i}"),
                machine: MACHINES[k % 3].to_string(),
                scaleout: 2 + k as u32,
                job_features: vec![(i * 1000 + k) as f64 + 0.5],
                runtime_s: 100.0 + (i * 37 + k * 11) as f64,
            })
            .collect();
        p.share(&RuntimeDataRepo::from_records(JobKind::Sort, records))
            .unwrap();
    }
    mesh_bootstrap(&mut peers);
    mesh_until_quiescent(&mut peers, &[JobKind::Sort], 1, 32);

    // the mesh raised floors, and truncation reached the durable store
    let repo_a = peers[0].repo(JobKind::Sort).unwrap().clone();
    assert!(repo_a.log_floor("org-0") > 0, "floor rose on the durable peer");
    assert_eq!(repo_a.retained_log_entries(), 0);
    drop(peers);

    // cold reopen: floors, records, digests all recover bitwise from
    // the compacted WAL + floor sidecar
    let reopened = Coordinator::open_with_store(cloud, &no_artifacts, 99, &root_a).unwrap();
    let repo_2 = reopened.repo(JobKind::Sort).unwrap();
    assert_eq!(repo_2.records(), repo_a.records(), "corpus recovered bitwise");
    assert_eq!(repo_2.watermarks(), repo_a.watermarks(), "floors + digests recovered");
    assert_eq!(repo_2.content_digest(), repo_a.content_digest());
    assert_eq!(repo_2.generation(), repo_a.generation());
    assert_eq!(repo_2.retained_log_entries(), 0, "truncation survived the reopen");
    let _ = std::fs::remove_dir_all(root_a);
    let _ = std::fs::remove_dir_all(root_b);
}

#[test]
fn below_floor_and_v2_peers_still_converge_against_truncated_logs() {
    let cloud = Cloud::aws_like();
    let mut peers: Vec<Coordinator> = (0..2)
        .map(|i| {
            let mut c = peer(&cloud, 500 + i as u64);
            c.set_mesh_name(&format!("peer-{i}"));
            c
        })
        .collect();
    for (i, p) in peers.iter_mut().enumerate() {
        let records: Vec<RuntimeRecord> = (0..6usize)
            .map(|k| RuntimeRecord {
                job: JobKind::Sort,
                org: format!("org-{i}"),
                machine: MACHINES[k % 3].to_string(),
                scaleout: 2 + k as u32,
                job_features: vec![(i * 1000 + k) as f64 + 0.5],
                runtime_s: 50.0 + (i * 13 + k * 7) as f64,
            })
            .collect();
        p.share(&RuntimeDataRepo::from_records(JobKind::Sort, records))
            .unwrap();
    }
    mesh_bootstrap(&mut peers);
    mesh_until_quiescent(&mut peers, &[JobKind::Sort], 1, 32);
    assert_eq!(peers[0].repo(JobKind::Sort).unwrap().retained_log_entries(), 0);

    // a late v3 joiner sits below every floor: its pull is answered
    // with whole-org snapshots, adopted bitwise
    let mut late = peer(&cloud, 510);
    let summary = sync(
        &mut late,
        &mut peers[0],
        &SyncOptions {
            scope: SyncScope::Job(JobKind::Sort),
            ..SyncOptions::default()
        },
    )
    .unwrap();
    assert!(
        summary.stats.snapshots > 0,
        "below-floor pull falls back to whole-org snapshots: {summary:?}"
    );
    let late_repo = late.repo(JobKind::Sort).unwrap();
    let truncated = peers[0].repo(JobKind::Sort).unwrap();
    assert_eq!(late_repo.canonical_records(), truncated.canonical_records());
    assert_eq!(late_repo.content_digest(), truncated.content_digest());
    assert_eq!(late_repo.watermarks(), truncated.watermarks(), "floors adopt too");
    // adoption is idempotent: the next exchange moves nothing
    let again = sync_job(&mut late, &mut peers[0], JobKind::Sort).unwrap();
    assert!(again.quiescent(), "snapshot adoption re-offers nothing: {again:?}");

    // a legacy v2 deployment exchanges holdings summaries, which never
    // reference folded history — the floors are invisible to it
    let mut legacy = peer(&cloud, 511);
    let stats = sync_job_v2(&mut legacy, &mut peers[0], JobKind::Sort).unwrap();
    assert_eq!(stats.snapshots, 0, "v2 ships holdings, not snapshots");
    assert_eq!(
        legacy.repo(JobKind::Sort).unwrap().canonical_records(),
        peers[0].repo(JobKind::Sort).unwrap().canonical_records(),
        "the v2 peer converges content-wise despite the truncated log"
    );
}
