//! Fixture: serving zone — `lock-discipline` (violation, allowed
//! nesting, suppression, `holds`) and `no-anyhow-public`.

use std::sync::Mutex;

pub struct State {
    pub shard_lock: Mutex<u32>,
    pub metrics: Mutex<u32>,
    pub snapshot: Mutex<u32>,
}

pub fn nested_wrong_order(s: &State) -> u32 {
    let shard = s.shard_lock.lock();
    let m = s.metrics.lock();
    drop(m);
    drop(shard);
    0
}

pub fn nested_allowed(s: &State) -> u32 {
    let shard = s.shard_lock.lock();
    let snap = s.snapshot.lock();
    drop(snap);
    drop(shard);
    0
}

pub fn nested_suppressed(s: &State) -> u32 {
    let shard = s.shard_lock.lock();
    // c3o-lint: allow(lock-discipline) — fixture: metrics fold is deadlock-free by construction
    let m = s.metrics.lock();
    drop(m);
    drop(shard);
    0
}

// c3o-lint: holds(shard) — fixture: caller acquires the shard guard before calling
pub fn publish_under_shard(s: &State) -> u32 {
    let snap = s.snapshot.lock();
    drop(snap);
    0
}

// c3o-lint: holds(shard) — fixture: caller already holds the shard guard
pub fn fold_under_shard(s: &State) -> u32 {
    let m = s.metrics.lock();
    drop(m);
    0
}

pub fn load(path: &str) -> anyhow::Result<u32> {
    let _ = path;
    Ok(0)
}

// c3o-lint: allow(no-anyhow-public) — fixture: documented boundary fold-in point
pub fn load_justified(path: &str) -> anyhow::Result<u32> {
    let _ = path;
    Ok(0)
}
