//! A lightweight Rust tokenizer — just enough lexical structure for the
//! invariant rules: identifiers, literals, punctuation (with `::`,
//! `->`, `=>` joined), line numbers, and the text of `//` comments (the
//! carrier for `c3o-lint:` suppression directives).
//!
//! Deliberately NOT a parser: no expression trees, no type resolution.
//! Every rule is written against token patterns plus brace matching,
//! which keeps the analyzer dependency-free and the heuristics easy to
//! audit (each rule documents its exact trigger pattern in README.md).

/// Kind of one lexed token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (the lexer does not distinguish).
    Ident,
    /// Lifetime (`'a`) — kept distinct so char literals never leak.
    Lifetime,
    /// Integer literal (including hex/octal/binary and int suffixes).
    Int,
    /// Float literal (contains `.`, an exponent, or an `f32`/`f64` suffix).
    Float,
    /// String literal (regular, raw, byte — contents dropped).
    Str,
    /// Char literal.
    Char,
    /// Punctuation. Multi-char tokens are only `::`, `->`, `=>`;
    /// everything else (including `>` of `>>`) is one char per token.
    Punct,
}

/// One token with its source line (1-based).
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }
    pub fn is_punct(&self, s: &str) -> bool {
        self.kind == TokKind::Punct && self.text == s
    }
}

/// A `//` comment, preserved for directive parsing.
#[derive(Debug, Clone)]
pub struct Comment {
    /// Text after the `//` (or `///`, `//!`) marker, untrimmed.
    pub text: String,
    pub line: u32,
    /// True when at least one token precedes the comment on its line
    /// (a *trailing* comment, e.g. `let x = m.lock(); // c3o-lint: ...`).
    pub trailing: bool,
}

/// Lex one source file. Never fails: unterminated constructs consume to
/// end of input (the real toolchain rejects such files anyway).
pub fn lex(src: &str) -> (Vec<Tok>, Vec<Comment>) {
    let b: Vec<char> = src.chars().collect();
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let mut line_had_token = false;

    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            line += 1;
            line_had_token = false;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (also doc comments).
        if c == '/' && i + 1 < b.len() && b[i + 1] == '/' {
            let mut j = i + 2;
            while j < b.len() && b[j] == '/' {
                j += 1; // swallow the doc-comment marker
            }
            if j < b.len() && b[j] == '!' {
                j += 1;
            }
            let start = j;
            while j < b.len() && b[j] != '\n' {
                j += 1;
            }
            comments.push(Comment {
                text: b[start..j].iter().collect(),
                line,
                trailing: line_had_token,
            });
            i = j;
            continue;
        }
        // Block comment (nested).
        if c == '/' && i + 1 < b.len() && b[i + 1] == '*' {
            let mut depth = 1;
            let mut j = i + 2;
            while j < b.len() && depth > 0 {
                if b[j] == '\n' {
                    line += 1;
                    line_had_token = false;
                } else if b[j] == '/' && j + 1 < b.len() && b[j + 1] == '*' {
                    depth += 1;
                    j += 1;
                } else if b[j] == '*' && j + 1 < b.len() && b[j + 1] == '/' {
                    depth -= 1;
                    j += 1;
                }
                j += 1;
            }
            i = j;
            continue;
        }
        // Raw strings / raw idents / byte strings: r"..."  r#"..."#  r#ident  b"..."  br#"..."#
        if (c == 'r' || c == 'b') && raw_or_byte_string_start(&b, i) {
            let (j, newlines) = skip_string_like(&b, i);
            toks.push(Tok {
                kind: TokKind::Str,
                text: String::new(),
                line,
            });
            line += newlines;
            i = j;
            line_had_token = true;
            continue;
        }
        if c == 'r' && i + 1 < b.len() && b[i + 1] == '#' && i + 2 < b.len() && is_ident_start(b[i + 2]) {
            // raw identifier r#foo
            let mut j = i + 2;
            while j < b.len() && is_ident_continue(b[j]) {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Ident,
                text: b[i + 2..j].iter().collect(),
                line,
            });
            i = j;
            line_had_token = true;
            continue;
        }
        if is_ident_start(c) {
            let mut j = i + 1;
            while j < b.len() && is_ident_continue(b[j]) {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Ident,
                text: b[i..j].iter().collect(),
                line,
            });
            i = j;
            line_had_token = true;
            continue;
        }
        if c.is_ascii_digit() {
            let (j, kind) = lex_number(&b, i);
            toks.push(Tok {
                kind,
                text: b[i..j].iter().collect(),
                line,
            });
            i = j;
            line_had_token = true;
            continue;
        }
        if c == '"' {
            let (j, newlines) = skip_string_like(&b, i);
            toks.push(Tok {
                kind: TokKind::Str,
                text: String::new(),
                line,
            });
            line += newlines;
            i = j;
            line_had_token = true;
            continue;
        }
        if c == '\'' {
            // Lifetime vs char literal: 'a followed by a non-quote is a
            // lifetime; anything else ('x', '\n', '\'') is a char.
            if i + 1 < b.len()
                && is_ident_start(b[i + 1])
                && !(i + 2 < b.len() && b[i + 2] == '\'')
            {
                let mut j = i + 1;
                while j < b.len() && is_ident_continue(b[j]) {
                    j += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Lifetime,
                    text: b[i + 1..j].iter().collect(),
                    line,
                });
                i = j;
            } else {
                let mut j = i + 1;
                if j < b.len() && b[j] == '\\' {
                    j += 2; // escape + escaped char
                } else {
                    j += 1;
                }
                while j < b.len() && b[j] != '\'' {
                    j += 1; // e.g. '\u{1f600}'
                }
                toks.push(Tok {
                    kind: TokKind::Char,
                    text: String::new(),
                    line,
                });
                i = j + 1;
            }
            line_had_token = true;
            continue;
        }
        // Punctuation; join only ::, ->, =>.
        let two: String = b[i..(i + 2).min(b.len())].iter().collect();
        let text = if two == "::" || two == "->" || two == "=>" {
            i += 2;
            two
        } else {
            i += 1;
            c.to_string()
        };
        toks.push(Tok {
            kind: TokKind::Punct,
            text,
            line,
        });
        line_had_token = true;
    }
    (toks, comments)
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Does position `i` (at `r` or `b`) start a raw/byte string?
fn raw_or_byte_string_start(b: &[char], i: usize) -> bool {
    let mut j = i;
    if b[j] == 'b' {
        j += 1;
        if j < b.len() && b[j] == 'r' {
            j += 1;
        }
    } else if b[j] == 'r' {
        j += 1;
    } else {
        return false;
    }
    while j < b.len() && b[j] == '#' {
        j += 1;
    }
    j < b.len() && b[j] == '"'
}

/// Skip a string literal starting at `i` (regular `"`, raw `r#"`, byte
/// `b"`). Returns (index past the literal, newline count inside it).
fn skip_string_like(b: &[char], i: usize) -> (usize, u32) {
    let mut j = i;
    let mut hashes = 0usize;
    let mut raw = false;
    if b[j] == 'b' {
        j += 1;
    }
    if j < b.len() && b[j] == 'r' {
        raw = true;
        j += 1;
        while j < b.len() && b[j] == '#' {
            hashes += 1;
            j += 1;
        }
    }
    debug_assert!(j < b.len() && b[j] == '"');
    j += 1; // opening quote
    let mut newlines = 0u32;
    while j < b.len() {
        if b[j] == '\n' {
            newlines += 1;
            j += 1;
            continue;
        }
        if !raw && b[j] == '\\' {
            // an escaped newline (line-continuation `\` at end of line)
            // still advances the source line
            if j + 1 < b.len() && b[j + 1] == '\n' {
                newlines += 1;
            }
            j += 2;
            continue;
        }
        if b[j] == '"' {
            if raw {
                // need `"` followed by `hashes` hash marks
                let mut k = j + 1;
                let mut seen = 0usize;
                while k < b.len() && b[k] == '#' && seen < hashes {
                    k += 1;
                    seen += 1;
                }
                if seen == hashes {
                    return (k, newlines);
                }
                j += 1;
                continue;
            }
            return (j + 1, newlines);
        }
        j += 1;
    }
    (j, newlines)
}

/// Lex a numeric literal starting at a digit. Float iff it has a
/// fractional part (`1.5`), a decimal exponent (`1e3`), or an explicit
/// `f32`/`f64` suffix — `1..2` and `1.max(2)` stay integers.
fn lex_number(b: &[char], i: usize) -> (usize, TokKind) {
    let mut j = i;
    let mut float = false;
    if b[j] == '0' && j + 1 < b.len() && (b[j + 1] == 'x' || b[j + 1] == 'o' || b[j + 1] == 'b') {
        j += 2;
        while j < b.len() && (b[j].is_ascii_alphanumeric() || b[j] == '_') {
            j += 1;
        }
        return (j, TokKind::Int);
    }
    while j < b.len() && (b[j].is_ascii_digit() || b[j] == '_') {
        j += 1;
    }
    if j < b.len() && b[j] == '.' && j + 1 < b.len() && b[j + 1].is_ascii_digit() {
        float = true;
        j += 1;
        while j < b.len() && (b[j].is_ascii_digit() || b[j] == '_') {
            j += 1;
        }
    } else if j < b.len() && b[j] == '.' && !(j + 1 < b.len() && (b[j + 1] == '.' || is_ident_start(b[j + 1]))) {
        // trailing-dot float like `1.`
        float = true;
        j += 1;
    }
    if j < b.len() && (b[j] == 'e' || b[j] == 'E') {
        let mut k = j + 1;
        if k < b.len() && (b[k] == '+' || b[k] == '-') {
            k += 1;
        }
        if k < b.len() && b[k].is_ascii_digit() {
            float = true;
            j = k;
            while j < b.len() && (b[j].is_ascii_digit() || b[j] == '_') {
                j += 1;
            }
        }
    }
    // suffix (u32, i64, f32, usize, ...)
    let sfx_start = j;
    while j < b.len() && is_ident_continue(b[j]) {
        j += 1;
    }
    let sfx: String = b[sfx_start..j].iter().collect();
    if sfx == "f32" || sfx == "f64" {
        float = true;
    }
    (j, if float { TokKind::Float } else { TokKind::Int })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).0.into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn numbers_floats_vs_ints() {
        let ks = kinds("1 1.5 1e3 0x1E 1..2 1.max(2) 3f64 4u32");
        let floats: Vec<&str> = ks
            .iter()
            .filter(|(k, _)| *k == TokKind::Float)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(floats, vec!["1.5", "1e3", "3f64"]);
        assert!(ks.iter().any(|(k, t)| *k == TokKind::Int && t == "0x1E"));
    }

    #[test]
    fn lifetimes_are_not_chars() {
        let ks = kinds("fn f<'a>(x: &'a str) { let c = 'x'; }");
        assert!(ks.iter().any(|(k, t)| *k == TokKind::Lifetime && t == "a"));
        assert_eq!(ks.iter().filter(|(k, _)| *k == TokKind::Char).count(), 1);
    }

    #[test]
    fn comments_captured_with_trailing_flag() {
        let (_, cs) = lex("let x = 1; // trailing\n// leading\nlet y = 2;");
        assert_eq!(cs.len(), 2);
        assert!(cs[0].trailing);
        assert!(!cs[1].trailing);
        assert_eq!(cs[1].text.trim(), "leading");
    }

    #[test]
    fn raw_strings_and_nested_block_comments() {
        let (toks, cs) = lex("let s = r#\"has \"quotes\" and // not a comment\"#; /* a /* b */ c */ x");
        assert!(cs.is_empty());
        assert!(toks.iter().any(|t| t.is_ident("x")));
        assert_eq!(toks.iter().filter(|t| t.kind == TokKind::Str).count(), 1);
    }

    #[test]
    fn multiline_string_advances_lines() {
        let (toks, _) = lex("let a = \"x\ny\";\nlet b = 1;");
        let b_tok = toks.iter().find(|t| t.is_ident("b")).unwrap();
        assert_eq!(b_tok.line, 3);
    }

    #[test]
    fn escaped_newline_in_string_advances_lines() {
        // a line-continuation `\` swallows the newline lexically, but
        // the token after the string is still on source line 3
        let (toks, _) = lex("let a = \"x \\\n y\";\nlet b = 1;");
        let b_tok = toks.iter().find(|t| t.is_ident("b")).unwrap();
        assert_eq!(b_tok.line, 3);
    }

    #[test]
    fn joined_punct() {
        let ks = kinds("a::b -> c => d >> e");
        let puncts: Vec<&str> = ks
            .iter()
            .filter(|(k, _)| *k == TokKind::Punct)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(puncts, vec!["::", "->", "=>", ">", ">"]);
    }
}
