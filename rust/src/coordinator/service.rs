//! The sharded, concurrent coordinator service — the "many organizations,
//! heavy traffic" deployment shape, with the protocol's read/write split
//! realized in the locking discipline.
//!
//! Architecture (contrast with the strictly-ordered single-worker
//! [`super::session`]):
//!
//! * **Shards** — one [`JobShard`] per [`JobKind`], each behind its own
//!   mutex, taken **only by writes** (`Submit`, `Contribute`, `Share`,
//!   `SyncPush`/`SyncPushAll`, and the acked-floor truncation a
//!   self-`MeshHello` triggers) — plus `SyncPull`/`SyncPullAll`, the
//!   reads that need the full record set for delta extraction. Distinct
//!   kinds never serialize against each other; same-kind writes
//!   serialize exactly as much as the shared repository requires. Mesh
//!   membership lives in its own leaf-class `mesh` mutex, never held
//!   while a shard lock is. With
//!   [`ServiceConfig::with_store_dir`] every shard persists its writes
//!   through a [`crate::store::JobStore`], and
//!   [`CoordinatorService::open`] recovers the corpus (and warms the
//!   models) from that store on startup.
//! * **Snapshots** — after every write, the shard publishes a
//!   generation-stamped immutable [`Arc<ModelSnapshot>`]: an atomic
//!   pointer swap under a write-only `RwLock` slot. Reads (`Recommend`,
//!   `SnapshotInfo`) clone the `Arc` and serve from it **without ever
//!   touching the shard mutex** — a hot job kind can retrain for seconds
//!   while its recommendations keep flowing.
//! * **Workers + lane affinity** — `N` threads pull requests from one
//!   shared **two-lane queue** ([`RequestQueue`]): reads (`Recommend`,
//!   `Metrics`, snapshot/watermark reads, sync pulls) in one lane,
//!   shard-mutating writes in the other. Every worker has a preferred
//!   lane — PJRT-pinned and even-numbered native workers drain reads
//!   first, odd native workers drain writes first — and **steals** from
//!   the other lane only when its own is empty, so a retrain-heavy
//!   write burst can't bury waiting recommendations (and no lane ever
//!   starves; steals are counted, see
//!   [`CoordinatorService::queue_steals`]). Every worker owns its **own
//!   model engine**, constructed on the worker's thread: the first
//!   `pjrt_workers` try to own a PJRT runtime (the PJRT client is
//!   thread-pinned, hence "pinned workers"); the rest always use the
//!   pure-Rust native engine ("free-floating"). Trained models are
//!   plain data stored in the shard/snapshot, padded to one fixed
//!   layout, so a model trained by any worker is served by every other.
//! * **Shared compute pool** — unless disabled
//!   ([`ServiceConfig::with_compute_pool`]), one
//!   [`crate::compute::ComputePool`] is shared by every shard and every
//!   native worker engine: retrains fan their CV folds across it and
//!   large predict batches split into row chunks, both with ordered
//!   reductions that keep results bitwise-identical to serial serving.
//! * **Per-request replies + tickets** — each request carries its own
//!   reply channel; [`ServiceClient::submit_nowait`] returns a
//!   [`SubmitTicket`] immediately so one client can pipeline many
//!   submissions and collect the outcomes later.
//! * **Coalesced reads** — a worker that dequeues a `Recommend` keeps
//!   popping the read lane while its front is a same-kind `Recommend`
//!   (up to [`ServiceConfig::coalesce`]) and scores all their
//!   candidates as **one** predict batch
//!   ([`ModelSnapshot::recommend_batch`]); each request still gets its
//!   own decision, bitwise-identical to uncoalesced serving (observable
//!   via `Metrics::coalesced_batches`). The drain is peek-based: a
//!   non-matching lane front stays queued for whichever worker gets to
//!   it — nothing is held back in worker-local backlogs.
//! * **Coalesced writes** — `Submit` gets the same peek-based drain on
//!   the write lane: a same-kind submit group is pre-scored as one
//!   predict batch against the cached model before the
//!   contribute/retrain steps run one by one under the shard lock. Each
//!   member re-checks the model's identity before honouring its
//!   pre-scored decision (an earlier member's retrain invalidates the
//!   rest of the group, which then decide inside their own submit), so
//!   outcomes stay bitwise-identical to sequential serving (observable
//!   via `Metrics::coalesced_write_batches`).
//!
//! ```no_run
//! use c3o::api::Client as _;
//! use c3o::cloud::Cloud;
//! use c3o::configurator::JobRequest;
//! use c3o::coordinator::service::{CoordinatorService, ServiceConfig};
//! use c3o::coordinator::Organization;
//!
//! let service = CoordinatorService::spawn(Cloud::aws_like(), ServiceConfig::default());
//! let client = service.client(); // Clone one per client thread
//! let org = Organization::new("acme");
//! let outcome = client.submit(&org, JobRequest::sort(15.0)).unwrap();
//! println!("ran on {} x{}", outcome.machine, outcome.scaleout);
//! service.shutdown();
//! ```

// Serving zone: unwraps are outages. The module-scoped clippy promotion
// mirrors the repo lint's `no-panic-serving` rule (see rust/lint); every
// surviving panic site below carries a justified `c3o-lint: allow`.
#![cfg_attr(not(test), warn(clippy::unwrap_used))]

use crate::api::compat::{self, V2Host};
use crate::api::{
    self, ApiError, Client, Contribution, Recommendation, Response, SnapshotInfo,
};
use crate::cloud::Cloud;
use crate::compute::ComputePool;
use crate::configurator::{ClusterChoice, Configurator, JobRequest};
use crate::coordinator::shard::{JobShard, ModelSnapshot, ShardPolicy};
use crate::coordinator::{JobOutcome, Metrics, Organization};
use crate::models::{Engine, ModelTrainer, QueryBatch};
use crate::obs::{Collector, ReqKind, Stage, Trace};
use crate::repo::{OrgWatermarkV2, RuntimeDataRepo, RuntimeRecord};
use crate::runtime::Runtime;
use crate::store::mesh::MeshState;
use crate::util::rng::Pcg32;
use crate::util::sync::{LockExt, RwLockExt};
use crate::workloads::JobKind;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

/// Deployment knobs for a [`CoordinatorService`].
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Worker threads serving the request queue.
    pub workers: usize,
    /// How many of the workers attempt to own a PJRT runtime (pinned);
    /// the remainder always run the native engine. Ignored when the
    /// artifacts are absent — every worker then falls back to native.
    pub pjrt_workers: usize,
    /// Artifacts directory for the PJRT-capable workers.
    pub artifacts_dir: PathBuf,
    /// Retrain/cold-start policy applied by every shard.
    pub policy: ShardPolicy,
    /// Master seed; each shard derives its own RNG stream from it.
    pub seed: u64,
    /// Maximum same-kind `Recommend` (or `Submit`) requests a worker
    /// coalesces into one predict batch (1 disables coalescing).
    pub coalesce: usize,
    /// Segment-store root for a **durable** service: repositories are
    /// recovered from it on startup (models warmed from the recovered
    /// corpora) and every write persists through it. `None` (default)
    /// keeps the service in-memory.
    pub store_dir: Option<PathBuf>,
    /// Structured request tracing ([`crate::obs`]). Behaviorally inert
    /// either way — decisions are bitwise-identical with tracing on or
    /// off (asserted by the shared client suite) — so it defaults on.
    pub tracing: bool,
    /// Share one [`crate::compute::ComputePool`] across every shard
    /// (parallel CV fans during retrains) and every native worker
    /// engine (chunked predict batches). Behaviorally inert — pooled
    /// results are bitwise-identical to serial serving (asserted by the
    /// shared client suite) — so it defaults on.
    pub compute_pool: bool,
    /// This deployment's mesh name: its identity in the gossip roster
    /// (peers derive the stable member ID from it, see
    /// [`crate::store::mesh::peer_id`]). Deployments that never join a
    /// mesh can leave the default.
    pub mesh_name: String,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: std::thread::available_parallelism()
                .map(|n| n.get().min(8))
                .unwrap_or(4),
            pjrt_workers: 1,
            artifacts_dir: Runtime::default_dir(),
            policy: ShardPolicy::default(),
            seed: 0xC30,
            coalesce: 16,
            store_dir: None,
            tracing: true,
            compute_pool: true,
            mesh_name: "c3o".to_string(),
        }
    }
}

impl ServiceConfig {
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_policy(mut self, policy: ShardPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_artifacts_dir(mut self, dir: PathBuf) -> Self {
        self.artifacts_dir = dir;
        self
    }

    /// How many workers attempt to own a PJRT runtime. `0` forces every
    /// worker onto the native engine (e.g. for backend-controlled
    /// benchmarks).
    pub fn with_pjrt_workers(mut self, pjrt_workers: usize) -> Self {
        self.pjrt_workers = pjrt_workers;
        self
    }

    /// Cap (or disable, with `1`) cross-request `Recommend` coalescing.
    pub fn with_coalesce(mut self, coalesce: usize) -> Self {
        self.coalesce = coalesce.max(1);
        self
    }

    /// Make the service durable: recover from (and persist through) the
    /// segment store rooted at `dir`. Use [`CoordinatorService::open`]
    /// to surface store errors instead of panicking.
    pub fn with_store_dir(mut self, dir: PathBuf) -> Self {
        self.store_dir = Some(dir);
        self
    }

    /// Enable or disable structured request tracing ([`crate::obs`]).
    pub fn with_tracing(mut self, tracing: bool) -> Self {
        self.tracing = tracing;
        self
    }

    /// Enable or disable the shared compute pool (parallel CV fans and
    /// chunked predict batches). Decisions are bitwise-identical either
    /// way; `false` pins all model math to the serving thread.
    pub fn with_compute_pool(mut self, compute_pool: bool) -> Self {
        self.compute_pool = compute_pool;
        self
    }

    /// Name this deployment in the gossip mesh (its roster identity).
    pub fn with_mesh_name(mut self, name: &str) -> Self {
        self.mesh_name = name.to_string();
        self
    }
}

/// Reply channel of one in-flight protocol request.
type ReplyTx = mpsc::Sender<Result<Response, ApiError>>;

/// One queued protocol request paired with its own reply channel (no
/// cross-client ordering) and its enqueue instant (drives the
/// `queue_wait` trace span; carried even when tracing is off so the
/// queue shape is identical either way).
struct WorkItem {
    request: Box<api::Request>,
    reply: ReplyTx,
    queued_at: Instant,
}

/// Which of the queue's two lanes a request lands in / a worker
/// prefers to drain.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Lane {
    /// Served without mutating any shard: `Recommend`, `Metrics`,
    /// `SnapshotInfo`, watermark reads, sync pulls.
    Read,
    /// Takes a shard mutex to mutate: `Submit`, `Contribute`, `Share`,
    /// sync pushes.
    Write,
}

/// Classify a request into its queue lane.
fn lane_of(request: &api::Request) -> Lane {
    match request {
        api::Request::Recommend { .. }
        | api::Request::Metrics
        | api::Request::SnapshotInfo { .. }
        | api::Request::Watermarks { .. }
        | api::Request::WatermarksAll
        | api::Request::WatermarksV2 { .. }
        | api::Request::SyncPull { .. }
        | api::Request::SyncPullAll { .. }
        | api::Request::SyncPullV2 { .. }
        | api::Request::MeshRoster => Lane::Read,
        api::Request::Submit { .. }
        | api::Request::Contribute { .. }
        | api::Request::Share { .. }
        | api::Request::SyncPush { .. }
        | api::Request::SyncPushAll { .. }
        // a self-hello ticks the anti-entropy round and may truncate
        // shard op logs, so hellos ride the write lane
        | api::Request::MeshHello { .. }
        | api::Request::SyncPushV2 { .. } => Lane::Write,
    }
}

/// Both lanes plus the shutdown tokens, guarded by one mutex.
struct Lanes {
    reads: VecDeque<WorkItem>,
    writes: VecDeque<WorkItem>,
    /// Outstanding shutdown tokens; consuming one exits a worker, and
    /// tokens are consumed only when both lanes are empty.
    shutdown: usize,
    /// A closed queue rejects new pushes (the service is shutting
    /// down); already-accepted requests still drain.
    closed: bool,
}

/// The service's two-lane request queue: request-class worker affinity.
///
/// Requests are split by [`lane_of`]. Every worker has a preferred lane
/// and drains it first, **stealing** from the other lane only when its
/// own is empty — so a retrain-heavy write burst cannot bury waiting
/// `Recommend`s behind it (and vice versa), while neither lane can
/// starve: an idle worker always steals. Steals are counted per
/// direction for observability ([`CoordinatorService::queue_steals`]).
///
/// Shutdown drains first: [`RequestQueue::close`] rejects new pushes
/// immediately, but workers consume shutdown tokens only once **both**
/// lanes are empty, so every accepted request is served before the
/// worker pool exits.
struct RequestQueue {
    /// Lock class `queue` (leaf: held only for queue surgery, never
    /// while serving or while any shard lock is held).
    queue: Mutex<Lanes>,
    ready: Condvar,
    /// Reads taken by write-affine workers whose own lane was empty.
    reads_stolen: AtomicU64,
    /// Writes taken by read-affine workers whose own lane was empty.
    writes_stolen: AtomicU64,
}

impl RequestQueue {
    fn new() -> RequestQueue {
        RequestQueue {
            queue: Mutex::new(Lanes {
                reads: VecDeque::new(),
                writes: VecDeque::new(),
                shutdown: 0,
                closed: false,
            }),
            ready: Condvar::new(),
            reads_stolen: AtomicU64::new(0),
            writes_stolen: AtomicU64::new(0),
        }
    }

    /// Enqueue one request. Fails with [`ApiError::Stopped`] once the
    /// service began shutting down.
    fn push(&self, request: Box<api::Request>, reply: ReplyTx) -> Result<(), ApiError> {
        {
            let mut lanes = self.queue.lock_unpoisoned();
            if lanes.closed {
                return Err(ApiError::Stopped);
            }
            let item = WorkItem {
                queued_at: Instant::now(),
                request,
                reply,
            };
            match lane_of(&item.request) {
                Lane::Read => lanes.reads.push_back(item),
                Lane::Write => lanes.writes.push_back(item),
            }
        }
        self.ready.notify_one();
        Ok(())
    }

    /// Dequeue for a worker with lane preference `affinity`: own lane
    /// first, steal from the other when empty, and consume a shutdown
    /// token (returning `None`) only when both lanes are empty.
    fn pop(&self, affinity: Lane) -> Option<WorkItem> {
        let mut lanes = self.queue.lock_unpoisoned();
        loop {
            let all = &mut *lanes;
            let (own, other, steal_counter) = match affinity {
                Lane::Read => (&mut all.reads, &mut all.writes, &self.writes_stolen),
                Lane::Write => (&mut all.writes, &mut all.reads, &self.reads_stolen),
            };
            if let Some(item) = own.pop_front() {
                return Some(item);
            }
            if let Some(item) = other.pop_front() {
                steal_counter.fetch_add(1, Ordering::Relaxed);
                return Some(item);
            }
            if lanes.shutdown > 0 {
                lanes.shutdown -= 1;
                return None;
            }
            lanes = match self.ready.wait(lanes) {
                Ok(guard) => guard,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }

    /// Peek-based read coalescing: pop the front of the read lane only
    /// if it is a `Recommend` for `kind`. A non-matching front stays
    /// queued for whichever worker gets to it — assembling a batch
    /// never delays or reorders unrelated requests.
    fn pop_coalesced_recommend(&self, kind: JobKind) -> Option<(JobRequest, ReplyTx)> {
        let mut lanes = self.queue.lock_unpoisoned();
        match lanes.reads.front().map(|item| item.request.as_ref()) {
            Some(api::Request::Recommend { request }) if request.kind() == kind => {}
            _ => return None,
        }
        let item = lanes.reads.pop_front()?;
        match *item.request {
            api::Request::Recommend { request } => Some((request, item.reply)),
            // unreachable (the front was checked under this same lock);
            // requeue rather than panic in the serving zone
            other => {
                lanes.reads.push_front(WorkItem {
                    request: Box::new(other),
                    reply: item.reply,
                    queued_at: item.queued_at,
                });
                None
            }
        }
    }

    /// Peek-based write coalescing: pop the front of the write lane
    /// only if it is a `Submit` for `kind` (see
    /// [`RequestQueue::pop_coalesced_recommend`]).
    fn pop_coalesced_submit(&self, kind: JobKind) -> Option<(Organization, JobRequest, ReplyTx)> {
        let mut lanes = self.queue.lock_unpoisoned();
        match lanes.writes.front().map(|item| item.request.as_ref()) {
            Some(api::Request::Submit { request, .. }) if request.kind() == kind => {}
            _ => return None,
        }
        let item = lanes.writes.pop_front()?;
        match *item.request {
            api::Request::Submit { org, request } => Some((org, request, item.reply)),
            // unreachable (the front was checked under this same lock);
            // requeue rather than panic in the serving zone
            other => {
                lanes.writes.push_front(WorkItem {
                    request: Box::new(other),
                    reply: item.reply,
                    queued_at: item.queued_at,
                });
                None
            }
        }
    }

    /// Begin shutdown: reject future pushes and leave one exit token
    /// per worker. Workers drain both lanes before consuming a token.
    fn close(&self, workers: usize) {
        {
            let mut lanes = self.queue.lock_unpoisoned();
            lanes.closed = true;
            lanes.shutdown += workers;
        }
        self.ready.notify_all();
    }
}

/// Shared state every worker sees.
struct Shared {
    /// Write-path state: taken only by `Submit`/`Contribute`/`Share`.
    shards: HashMap<JobKind, Mutex<JobShard>>,
    /// Read-path state: one immutable snapshot per shard, swapped by the
    /// write that changed it. Readers hold the `RwLock` only long enough
    /// to clone the `Arc`.
    snapshots: HashMap<JobKind, RwLock<Arc<ModelSnapshot>>>,
    metrics: Mutex<Metrics>,
    cloud: Cloud,
    policy: ShardPolicy,
    coalesce: usize,
    /// The shared compute pool (also installed into every shard);
    /// native worker engines adopt it for chunked predict batches.
    /// `None` when [`ServiceConfig::with_compute_pool`] disabled it.
    pool: Option<Arc<ComputePool>>,
    /// Trace collector: per-worker lock-free rings on the hot path,
    /// aggregation only at drain time ([`crate::obs`]).
    obs: Collector,
    /// Gossip-mesh membership + per-peer acked watermarks. Lock class
    /// `mesh` — a **leaf**: held only for roster surgery and acked-floor
    /// computation, never while a shard (or any other) lock is held;
    /// truncation locks the shards only after this lock is dropped.
    mesh: Mutex<MeshState>,
}

impl Shared {
    /// Swap in a fresh snapshot of `shard` (called with the shard lock
    /// held, so snapshot order matches write order; `shard -> snapshot`
    /// is a declared pair in the lint's lock-order table).
    // c3o-lint: holds(shard) — every caller swaps under the writing shard's lock so publish order matches write order
    fn publish(&self, shard: &JobShard) {
        let snap = Arc::new(shard.snapshot());
        if let Some(slot) = self.snapshots.get(&shard.job()) {
            *slot.write_unpoisoned() = snap;
        }
    }

    /// Clone the current snapshot `Arc` for a job — the whole read-path
    /// synchronization cost. (The snapshot map is total over
    /// `JobKind::all()`; an absent slot would mean a construction bug,
    /// answered with an empty snapshot rather than a panic.)
    fn snapshot(&self, job: JobKind) -> Arc<ModelSnapshot> {
        match self.snapshots.get(&job) {
            Some(slot) => Arc::clone(&slot.read_unpoisoned()),
            None => Arc::new(ModelSnapshot::empty(job)),
        }
    }
}

/// The running service: owns the worker threads and the request queue.
pub struct CoordinatorService {
    queue: Arc<RequestQueue>,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

/// A cloneable client handle; one per client thread. Each call blocks on
/// its own reply channel only.
#[derive(Clone)]
pub struct ServiceClient {
    queue: Arc<RequestQueue>,
}

/// Handle to a pipelined submission dispatched with
/// [`ServiceClient::submit_nowait`]: the request is in flight (or being
/// served) while the client does other work; [`SubmitTicket::wait`]
/// collects the outcome.
pub struct SubmitTicket {
    rx: mpsc::Receiver<Result<Response, ApiError>>,
    done: Option<Result<JobOutcome, ApiError>>,
}

impl SubmitTicket {
    fn unpack(result: Result<Response, ApiError>) -> Result<JobOutcome, ApiError> {
        match result? {
            Response::Submitted(outcome) => Ok(outcome),
            other => Err(ApiError::Protocol(format!(
                "submit ticket resolved to a non-Submitted response: {other:?}"
            ))),
        }
    }

    /// Block until the outcome arrives.
    pub fn wait(mut self) -> Result<JobOutcome, ApiError> {
        if let Some(done) = self.done.take() {
            return done;
        }
        match self.rx.recv() {
            Ok(result) => Self::unpack(result),
            Err(_) => Err(ApiError::Stopped),
        }
    }

    /// Non-blocking readiness poll; once `true`, [`SubmitTicket::wait`]
    /// returns immediately.
    pub fn is_ready(&mut self) -> bool {
        if self.done.is_some() {
            return true;
        }
        match self.rx.try_recv() {
            Ok(result) => {
                self.done = Some(Self::unpack(result));
                true
            }
            Err(mpsc::TryRecvError::Disconnected) => {
                self.done = Some(Err(ApiError::Stopped));
                true
            }
            Err(mpsc::TryRecvError::Empty) => false,
        }
    }
}

fn call_on(queue: &RequestQueue, request: api::Request) -> Result<Response, ApiError> {
    let (rtx, rrx) = mpsc::channel();
    queue.push(Box::new(request), rtx)?;
    rrx.recv().map_err(|_| ApiError::Stopped)?
}

impl ServiceClient {
    /// Execute one protocol request; blocks on this request's own reply
    /// channel only.
    pub fn call(&self, request: api::Request) -> Result<Response, ApiError> {
        call_on(&self.queue, request)
    }

    /// Merge shared runtime data into the owning shard's repository.
    pub fn share(&self, repo: RuntimeDataRepo) -> Result<Contribution, ApiError> {
        let mut this = self;
        Client::share(&mut this, repo)
    }

    /// Submit a job; blocks on this request's own reply only.
    pub fn submit(&self, org: &Organization, request: JobRequest) -> Result<JobOutcome, ApiError> {
        let mut this = self;
        Client::submit(&mut this, org, request)
    }

    /// Dispatch a submission without waiting: returns a ticket
    /// immediately so the client can pipeline further requests (and the
    /// worker pool can interleave/coalesce them) before collecting
    /// outcomes.
    pub fn submit_nowait(
        &self,
        org: &Organization,
        request: JobRequest,
    ) -> Result<SubmitTicket, ApiError> {
        request.validate()?;
        let (rtx, rrx) = mpsc::channel();
        self.queue.push(
            Box::new(api::Request::Submit {
                org: org.clone(),
                request,
            }),
            rtx,
        )?;
        Ok(SubmitTicket {
            rx: rrx,
            done: None,
        })
    }

    /// Read-only configuration recommendation, served lock-free from the
    /// job's published snapshot.
    pub fn recommend(&self, request: JobRequest) -> Result<Recommendation, ApiError> {
        let mut this = self;
        Client::recommend(&mut this, request)
    }

    /// Record one externally-observed run.
    pub fn contribute(&self, record: RuntimeRecord) -> Result<Contribution, ApiError> {
        let mut this = self;
        Client::contribute(&mut this, record)
    }

    /// Snapshot the service-wide metrics.
    pub fn metrics(&self) -> Result<Metrics, ApiError> {
        let mut this = self;
        Client::metrics(&mut this)
    }

    /// Describe the model snapshot serving a job's reads.
    pub fn snapshot_info(&self, job: JobKind) -> Result<SnapshotInfo, ApiError> {
        let mut this = self;
        Client::snapshot_info(&mut this, job)
    }
}

/// `ServiceClient` speaks the protocol (on `&ServiceClient` too, so a
/// shared handle serves the trait's `&mut self` methods — every call is
/// an independent request with its own reply channel).
impl Client for &ServiceClient {
    fn call(&mut self, request: api::Request) -> Result<Response, ApiError> {
        ServiceClient::call(*self, request)
    }
}

impl Client for ServiceClient {
    fn call(&mut self, request: api::Request) -> Result<Response, ApiError> {
        ServiceClient::call(self, request)
    }
}

impl CoordinatorService {
    /// Spawn the service: shards + published snapshots for every job
    /// kind plus `workers` threads, each constructing its engine on its
    /// own thread. Panics on a segment-store failure — durable
    /// deployments should prefer [`CoordinatorService::open`].
    pub fn spawn(cloud: Cloud, config: ServiceConfig) -> CoordinatorService {
        // c3o-lint: allow(no-panic-serving) — documented panicking constructor; durable deployments use `open` and get the typed error
        Self::open(cloud, config).expect("service construction failed")
    }

    /// Fallible constructor. For a durable config
    /// ([`ServiceConfig::with_store_dir`]) this recovers every job's
    /// repository from the segment store (newest snapshot + WAL
    /// replay), warms the model caches from the recovered corpora with
    /// a native engine, and publishes the recovered snapshots — so a
    /// restarted service answers `SnapshotInfo` with its pre-restart
    /// generation and serves `Recommend` before any new write arrives.
    pub fn open(cloud: Cloud, config: ServiceConfig) -> Result<CoordinatorService, ApiError> {
        let queue = Arc::new(RequestQueue::new());
        let pool = config
            .compute_pool
            .then(|| Arc::new(ComputePool::with_default_parallelism()));
        let mut seed_rng = Pcg32::new(config.seed);
        let mut shards = HashMap::new();
        let mut snapshots = HashMap::new();
        let mut boot_metrics = Metrics::default();
        // Recovery warm-up uses a native engine on this thread; workers
        // still build their own engines (incl. PJRT) below. Trained
        // model state is backend-portable, so this is only a boot cost.
        let mut warm_engine: Option<Engine> = None;
        for kind in JobKind::all() {
            let seed = seed_rng.next_u64();
            let mut shard = match &config.store_dir {
                None => JobShard::new(kind, seed),
                Some(root) => {
                    let (store, repo) = crate::store::JobStore::open(root, kind)?;
                    let mut shard = JobShard::recover(kind, seed, store, repo);
                    shard.refresh_model(
                        warm_engine.get_or_insert_with(Engine::native),
                        &cloud,
                        &config.policy,
                        &mut boot_metrics,
                    )?;
                    shard
                }
            };
            if let Some(pool) = &pool {
                shard.set_compute_pool(Arc::clone(pool));
            }
            snapshots.insert(kind, RwLock::new(Arc::new(shard.snapshot())));
            shards.insert(kind, Mutex::new(shard));
        }
        let n = config.workers.max(1);
        let shared = Arc::new(Shared {
            shards,
            snapshots,
            metrics: Mutex::new(boot_metrics),
            cloud,
            policy: config.policy.clone(),
            coalesce: config.coalesce.max(1),
            pool,
            obs: Collector::new(n, config.tracing),
            mesh: Mutex::new(MeshState::new(&config.mesh_name)),
        });
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let queue = Arc::clone(&queue);
            let shared = Arc::clone(&shared);
            let artifacts_dir = config.artifacts_dir.clone();
            let try_pjrt = i < config.pjrt_workers;
            workers.push(std::thread::spawn(move || {
                worker_loop(queue, shared, i, try_pjrt, artifacts_dir);
            }));
        }
        Ok(CoordinatorService {
            queue,
            shared,
            workers,
        })
    }

    /// A new client handle (clone freely across threads).
    pub fn client(&self) -> ServiceClient {
        ServiceClient {
            queue: Arc::clone(&self.queue),
        }
    }

    /// Merge shared runtime data (convenience over [`Self::client`]).
    pub fn share(&self, repo: RuntimeDataRepo) -> Result<Contribution, ApiError> {
        self.client().share(repo)
    }

    /// Submit a job (convenience over [`Self::client`]).
    pub fn submit(&self, org: &Organization, request: JobRequest) -> Result<JobOutcome, ApiError> {
        self.client().submit(org, request)
    }

    /// Read-only recommendation (convenience over [`Self::client`]).
    pub fn recommend(&self, request: JobRequest) -> Result<Recommendation, ApiError> {
        self.client().recommend(request)
    }

    /// Snapshot the service-wide metrics.
    pub fn metrics(&self) -> Result<Metrics, ApiError> {
        self.client().metrics()
    }

    /// Current repo generation of a shard — read off the published
    /// snapshot, no shard lock (observability / tests).
    pub fn generation(&self, kind: JobKind) -> u64 {
        self.shared.snapshot(kind).generation
    }

    /// The generation the shard's cached model was trained at — read off
    /// the published snapshot, no shard lock.
    pub fn trained_at_generation(&self, kind: JobKind) -> Option<u64> {
        self.shared
            .snapshot(kind)
            .model
            .as_ref()
            .map(|m| m.trained_at_gen)
    }

    /// Cross-lane steal counters of the affinity queue since startup:
    /// `(reads_stolen, writes_stolen)` — reads taken by write-affine
    /// workers and writes taken by read-affine workers, each because
    /// their own lane was empty. Observability for the request-class
    /// affinity router (tests and the serve bench read these).
    pub fn queue_steals(&self) -> (u64, u64) {
        (
            self.queue.reads_stolen.load(Ordering::Relaxed),
            self.queue.writes_stolen.load(Ordering::Relaxed),
        )
    }

    /// Drain and snapshot the observability aggregate: the per-kind ×
    /// per-stage latency histograms, the worst-K slow-request captures,
    /// and the drain/loss accounting — the `latency` block of
    /// `c3o serve --json`. Empty (and cheap) when tracing is disabled.
    pub fn obs_report(&self) -> crate::obs::Report {
        self.shared.obs.report()
    }

    /// Drain and render the retained trace window as Chrome
    /// trace-event JSON — the `c3o serve --trace-out FILE` payload,
    /// loadable in Perfetto / `chrome://tracing`.
    pub fn trace_export_json(&self) -> crate::util::json::Json {
        self.shared.obs.chrome_trace_json()
    }

    /// Test hook: grab a shard's write mutex, simulating a slow write /
    /// retrain holding the lock. Reads must keep completing while the
    /// guard is alive; same-kind writes must block.
    #[doc(hidden)]
    pub fn hold_shard_for_tests(&self, kind: JobKind) -> std::sync::MutexGuard<'_, JobShard> {
        // c3o-lint: allow(no-panic-serving) — test-only hook; the shard map is total over JobKind::all() by construction
        self.shared.shards[&kind].lock_unpoisoned()
    }

    /// Observability/test hook: a clone of a shard's repository (takes
    /// the shard lock briefly). The federation tests compare peers'
    /// repositories bitwise through this.
    #[doc(hidden)]
    pub fn repo_snapshot(&self, kind: JobKind) -> RuntimeDataRepo {
        // c3o-lint: allow(no-panic-serving) — test/observability hook; the shard map is total over JobKind::all() by construction
        self.shared.shards[&kind].lock_unpoisoned().repo().clone()
    }

    /// Spawn a background gossip loop that keeps this service's shared
    /// repositories in sync with `peers` (client handles of other
    /// deployments), exchanging deltas for `jobs` every `interval`.
    /// Stop it with [`SyncDriver::stop`]; it also stops when this
    /// service shuts down (the next exchange sees `ApiError::Stopped`).
    pub fn sync_with(
        &self,
        peers: Vec<ServiceClient>,
        jobs: Vec<JobKind>,
        interval: std::time::Duration,
    ) -> crate::store::SyncDriver {
        crate::store::SyncDriver::spawn(self.client(), peers, jobs, interval)
    }

    /// Spawn a background **mesh** gossip loop: each round self-ticks
    /// this deployment (advancing its anti-entropy round, evicting
    /// stale roster members, and folding acked log prefixes below the
    /// truncation floor), then runs one batched cross-job exchange with
    /// each of `fanout` roster-selected peers. Supersedes
    /// [`CoordinatorService::sync_with`]'s static peer list: peers are
    /// chosen from the live roster each round. Stop it with
    /// [`crate::store::MeshDriver::stop`].
    pub fn mesh_with(
        &self,
        peers: Vec<(String, ServiceClient)>,
        fanout: usize,
        interval: std::time::Duration,
    ) -> crate::store::MeshDriver {
        crate::store::MeshDriver::spawn(self.client(), peers, fanout, interval)
    }

    /// Graceful shutdown: every worker drains one `Shutdown` and exits.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.queue.close(self.workers.len());
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

impl Drop for CoordinatorService {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Classify a protocol request for latency keying.
fn req_kind(request: &api::Request) -> ReqKind {
    match request {
        api::Request::Recommend { .. } => ReqKind::Recommend,
        api::Request::Submit { .. } => ReqKind::Submit,
        api::Request::Contribute { .. } => ReqKind::Contribute,
        api::Request::Share { .. } => ReqKind::Share,
        api::Request::Watermarks { .. }
        | api::Request::WatermarksAll
        | api::Request::SyncPull { .. }
        | api::Request::SyncPush { .. }
        | api::Request::SyncPullAll { .. }
        | api::Request::SyncPushAll { .. }
        | api::Request::MeshHello { .. }
        | api::Request::MeshRoster
        | api::Request::WatermarksV2 { .. }
        | api::Request::SyncPullV2 { .. }
        | api::Request::SyncPushV2 { .. } => ReqKind::Sync,
        api::Request::Metrics | api::Request::SnapshotInfo { .. } => ReqKind::Other,
    }
}

/// Convert the shard's internally-measured stage durations (the
/// featurize/CV/winner-fit retrain split, WAL append + fsync) into
/// duration spans on `trace`, laid out back-to-front ending at the
/// drain instant: widths are exact, offsets reconstructed. Called with
/// the shard lock still held so the durations belong to this request
/// (or its coalesced group).
fn drain_shard_stages(trace: &mut Trace, shard: &mut JobShard) {
    let drained = shard.take_stage_nanos();
    // Walk the stage order backwards from the drain instant: the
    // latest-occurring stage (fsync) ends now, each earlier stage ends
    // where the next one started.
    let mut end_rel = trace.now_rel_ns();
    for stage in Stage::ALL.iter().rev().copied() {
        let dur = drained[stage.index()];
        trace.push_dur(stage, dur, end_rel);
        end_rel = end_rel.saturating_sub(dur);
    }
}

fn worker_loop(
    queue: Arc<RequestQueue>,
    shared: Arc<Shared>,
    worker: usize,
    try_pjrt: bool,
    artifacts_dir: PathBuf,
) {
    // Engines are per-worker and constructed on the worker's own thread:
    // the PJRT client is not `Send`, so a PJRT-owning worker is pinned to
    // its runtime for its whole life; native workers are pure data.
    let mut engine = if try_pjrt {
        Engine::auto(&artifacts_dir)
    } else {
        Engine::native()
    };
    // Native workers adopt the shared compute pool for chunked predict
    // batches (bitwise-identical to serial scoring).
    if let (Some(pool), Engine::Native(native)) = (&shared.pool, &mut engine) {
        native.set_compute_pool(Arc::clone(pool));
    }
    // Request-class affinity: PJRT-pinned workers and every even native
    // worker prefer the read lane (recommendations keep flowing while
    // writes retrain); odd native workers prefer the write lane. The
    // preference only biases — an idle worker always steals from the
    // other lane, so a single-worker service still serves everything.
    let affinity = if try_pjrt || worker % 2 == 0 {
        Lane::Read
    } else {
        Lane::Write
    };
    loop {
        // Hold the queue lock only for the dequeue, never while serving.
        let Some(WorkItem {
            request,
            reply,
            queued_at,
        }) = queue.pop(affinity)
        else {
            break; // consumed a shutdown token (both lanes were empty)
        };
        match *request {
            api::Request::Recommend { request } => {
                let mut trace = shared.obs.trace(ReqKind::Recommend, worker);
                trace.span_from(Stage::QueueWait, queued_at);
                let kind = request.kind();
                let mut group = vec![(request, reply)];
                // Opportunistically coalesce further same-kind reads:
                // keep popping while the read lane's front matches.
                {
                    let _assembly = trace.span(Stage::CoalesceAssembly);
                    while group.len() < shared.coalesce {
                        match queue.pop_coalesced_recommend(kind) {
                            Some(pair) => group.push(pair),
                            None => break,
                        }
                    }
                }
                trace.set_group(group.len() as u32);
                serve_recommend_group(&shared, &mut engine, kind, group, trace);
            }
            api::Request::Submit { org, request } => {
                let mut trace = shared.obs.trace(ReqKind::Submit, worker);
                trace.span_from(Stage::QueueWait, queued_at);
                let kind = request.kind();
                let mut group = vec![(org, request, reply)];
                // Same drain discipline on the write lane: pull further
                // same-kind `Submit`s so their candidate scoring shares
                // one predict batch.
                {
                    let _assembly = trace.span(Stage::CoalesceAssembly);
                    while group.len() < shared.coalesce {
                        match queue.pop_coalesced_submit(kind) {
                            Some(triple) => group.push(triple),
                            None => break,
                        }
                    }
                }
                trace.set_group(group.len() as u32);
                serve_submit_group(&shared, &mut engine, kind, group, trace);
            }
            other => {
                let mut trace = shared.obs.trace(req_kind(&other), worker);
                trace.span_from(Stage::QueueWait, queued_at);
                let result = serve_request(&shared, &mut engine, other, &mut trace);
                {
                    let _reply_span = trace.span(Stage::Reply);
                    let _ = reply.send(result);
                }
                shared.obs.ingest(trace);
            }
        }
    }
}

/// Serve a coalesced group of same-kind `Recommend`s from the published
/// snapshot — the lock-free read path: no shard mutex, one predict batch
/// for every candidate of every request.
fn serve_recommend_group(
    shared: &Shared,
    engine: &mut dyn ModelTrainer,
    kind: JobKind,
    group: Vec<(JobRequest, ReplyTx)>,
    mut trace: Trace,
) {
    let snap = shared.snapshot(kind);
    let mut local = Metrics::default();
    // validate before scoring; invalid requests drop out of the batch
    let mut valid: Vec<usize> = Vec::with_capacity(group.len());
    let mut results: Vec<Option<Result<Recommendation, ApiError>>> = vec![None; group.len()];
    for (i, (request, _)) in group.iter().enumerate() {
        match request.validate() {
            Ok(()) => valid.push(i),
            // c3o-lint: allow(no-panic-serving) — `i` enumerates `group`; `results` was sized to `group.len()` above
            Err(e) => results[i] = Some(Err(e)),
        }
    }
    if !valid.is_empty() {
        let requests: Vec<JobRequest> =
            // c3o-lint: allow(no-panic-serving) — `valid` holds indices produced by enumerating `group`
            valid.iter().map(|&i| group[i].0.clone()).collect();
        let served = {
            let _predict = trace.span(Stage::Predict);
            snap.recommend_batch(engine, &shared.cloud, &shared.policy, &requests)
        };
        if valid.len() > 1 {
            local.coalesced_batches += 1;
        }
        for (&i, result) in valid.iter().zip(served) {
            if result.is_ok() {
                local.recommends += 1;
            }
            // c3o-lint: allow(no-panic-serving) — `valid` indices come from enumerating `group`, and `results` spans `group`
            results[i] = Some(result);
        }
    }
    shared.metrics.lock_unpoisoned().fold(&local);
    {
        let _reply_span = trace.span(Stage::Reply);
        for ((_, reply), result) in group.into_iter().zip(results) {
            let result = result.unwrap_or_else(|| {
                Err(ApiError::Internal(
                    "recommend batch left a reply slot unfilled".to_string(),
                ))
            });
            let _ = reply.send(result.map(Response::Recommendation));
        }
    }
    shared.obs.ingest(trace);
}

/// Serve a coalesced group of same-kind `Submit`s. The per-submit
/// candidate scoring is hoisted out of the serialized write path: when
/// the shard has a cached model and the group has two or more members,
/// **every member's candidates are scored as one predict batch** —
/// exactly the arithmetic of [`ModelSnapshot::recommend_batch`] — before
/// the contribute/retrain steps run one by one in arrival order. Each
/// member re-checks that the model it was pre-scored against is still
/// the shard's cached model (an earlier member's retrain may have
/// replaced it) and falls back to deciding inside its own submit
/// otherwise, so decisions are bitwise-identical to serving the submits
/// sequentially (`Submit` and `Recommend` share one decision path).
fn serve_submit_group(
    shared: &Shared,
    engine: &mut dyn ModelTrainer,
    kind: JobKind,
    group: Vec<(Organization, JobRequest, ReplyTx)>,
    mut trace: Trace,
) {
    let mut local = Metrics::default();
    let mut results: Vec<Option<Result<JobOutcome, ApiError>>> =
        (0..group.len()).map(|_| None).collect();
    // validate before taking the shard lock; invalid requests drop out
    let mut valid: Vec<usize> = Vec::with_capacity(group.len());
    for (i, (_, request, _)) in group.iter().enumerate() {
        match request.validate() {
            Ok(()) => valid.push(i),
            // c3o-lint: allow(no-panic-serving) — `i` enumerates `group`; `results` was sized to `group.len()` above
            Err(e) => results[i] = Some(Err(e)),
        }
    }
    if !valid.is_empty() {
        match shard_for(shared, kind) {
            Err(e) => {
                for &i in &valid {
                    // c3o-lint: allow(no-panic-serving) — `valid` holds indices produced by enumerating `group`
                    results[i] = Some(Err(e.clone()));
                }
            }
            Ok(shard_mutex) => {
                let mut shard = {
                    let _lock_wait = trace.span(Stage::ShardLockWait);
                    shard_mutex.lock_unpoisoned()
                };
                // Pre-score all members' candidates as one batch
                // against the current cached model (same shape as the
                // read path). A scoring failure here is not an error:
                // the member just decides inside its own submit.
                let mut predecided: Vec<Option<ClusterChoice>> = vec![None; group.len()];
                let mut scored_model: Option<usize> = None;
                if valid.len() > 1 {
                    if let Some(cached) = shard.cached_model() {
                        let configurator = Configurator::new(&shared.cloud)
                            .with_machines(shard.observed_machines());
                        let pairs = configurator.enumerate();
                        if !pairs.is_empty() {
                            let batches: Vec<QueryBatch> = valid
                                .iter()
                                .map(|&i| {
                                    QueryBatch::from_candidates(
                                        &shared.cloud,
                                        &pairs,
                                        // c3o-lint: allow(no-panic-serving) — `valid` holds indices produced by enumerating `group`
                                        &group[i].1.spec.job_features(),
                                    )
                                })
                                .collect();
                            let combined = QueryBatch::concat(&batches);
                            let scored = {
                                let _predict = trace.span(Stage::Predict);
                                engine.predict_batch(&cached.model, &shared.cloud, &combined)
                            };
                            if let Ok(runtimes) = scored {
                                for (slot, &i) in valid.iter().enumerate() {
                                    let lo = slot * pairs.len();
                                    // c3o-lint: allow(no-panic-serving) — chunk bounds hold by construction (one runtime per concatenated candidate row)
                                    let chunk = &runtimes[lo..lo + pairs.len()];
                                    // c3o-lint: allow(no-panic-serving) — `valid` indices come from enumerating `group`; `predecided` spans `group`
                                    predecided[i] = configurator.choose(&group[i].1, &pairs, chunk);
                                }
                                scored_model = Some(Arc::as_ptr(cached) as usize);
                                local.coalesced_write_batches += 1;
                            }
                        }
                    }
                }
                for &i in &valid {
                    // c3o-lint: allow(no-panic-serving) — `valid` indices come from enumerating `group`; `predecided` spans `group`
                    let pre = match (predecided[i].take(), scored_model) {
                        // honour the pre-scored decision only while the
                        // model it was scored against is still cached
                        (Some(choice), Some(ptr))
                            if shard.cached_model().map(|m| Arc::as_ptr(m) as usize)
                                == Some(ptr) =>
                        {
                            Some(choice)
                        }
                        _ => None,
                    };
                    // c3o-lint: allow(no-panic-serving) — `valid` holds indices produced by enumerating `group`
                    let (org, request, _) = &group[i];
                    let outcome = shard.submit_predecided(
                        engine,
                        &shared.cloud,
                        &shared.policy,
                        &mut local,
                        org,
                        request,
                        pre,
                    );
                    if outcome.is_ok() {
                        shared.publish(&shard);
                    }
                    // c3o-lint: allow(no-panic-serving) — `valid` indices come from enumerating `group`, and `results` spans `group`
                    results[i] = Some(outcome);
                }
                drain_shard_stages(&mut trace, &mut shard);
            }
        }
    }
    // Fold after the shard lock drops, so the global metrics mutex
    // never nests inside a busy shard.
    shared.metrics.lock_unpoisoned().fold(&local);
    {
        let _reply_span = trace.span(Stage::Reply);
        for ((_, _, reply), result) in group.into_iter().zip(results) {
            let result = result.unwrap_or_else(|| {
                Err(ApiError::Internal(
                    "submit batch left a reply slot unfilled".to_string(),
                ))
            });
            let _ = reply.send(result.map(Response::Submitted));
        }
    }
    shared.obs.ingest(trace);
}

/// Serve one non-`Recommend`, non-`Submit` protocol request. Writes take
/// their shard's mutex and republish the snapshot before releasing it;
/// the remaining reads (`Metrics`, `SnapshotInfo`) touch no shard lock
/// at all.
fn serve_request(
    shared: &Shared,
    engine: &mut dyn ModelTrainer,
    request: api::Request,
    trace: &mut Trace,
) -> Result<Response, ApiError> {
    match request {
        api::Request::Contribute { record } => {
            api::validate_machines(&shared.cloud, std::slice::from_ref(&record))?;
            let kind = record.job;
            let shard_mutex = shard_for(shared, kind)?;
            let mut local = Metrics::default();
            let result = {
                let mut shard = {
                    let _lock_wait = trace.span(Stage::ShardLockWait);
                    shard_mutex.lock_unpoisoned()
                };
                let result = shard.contribute_record(record).and_then(|contribution| {
                    shard.refresh_model(engine, &shared.cloud, &shared.policy, &mut local)?;
                    shared.publish(&shard);
                    local.contributions += 1;
                    Ok(contribution)
                });
                drain_shard_stages(trace, &mut shard);
                result
            };
            shared.metrics.lock_unpoisoned().fold(&local);
            result.map(Response::Contributed)
        }
        api::Request::Share { repo } => {
            api::validate_machines(&shared.cloud, repo.records())?;
            let kind = repo.job();
            let shard_mutex = shard_for(shared, kind)?;
            let mut local = Metrics::default();
            let result = {
                let mut shard = {
                    let _lock_wait = trace.span(Stage::ShardLockWait);
                    shard_mutex.lock_unpoisoned()
                };
                let result = shard
                    .share(&repo)
                    .and_then(|outcome| {
                        shard.refresh_model(engine, &shared.cloud, &shared.policy, &mut local)?;
                        shared.publish(&shard);
                        Ok(Contribution {
                            job: kind,
                            added: outcome.added,
                            generation: shard.generation(),
                        })
                    });
                drain_shard_stages(trace, &mut shard);
                result
            };
            shared.metrics.lock_unpoisoned().fold(&local);
            result.map(Response::Shared)
        }
        api::Request::Metrics => Ok(Response::Metrics(shared.metrics.lock_unpoisoned().clone())),
        api::Request::SnapshotInfo { job } => {
            Ok(Response::SnapshotInfo(shared.snapshot(job).info()))
        }
        // Federation. `Watermarks` is served lock-free off the published
        // snapshot like every read. `SyncPull` (and the rare v2
        // compatibility reads) need the op logs / full record set, which
        // snapshots deliberately don't carry — they take the shard lock;
        // sync exchanges are rare and bandwidth-bound, not latency-bound.
        api::Request::Watermarks { job } => {
            let snap = shared.snapshot(job);
            Ok(Response::Watermarks(api::WatermarkSet {
                job,
                generation: snap.generation,
                watermarks: snap.watermarks.clone(),
            }))
        }
        api::Request::SyncPull { job, watermarks } => {
            Ok(Response::SyncDelta(pull_delta(shared, job, &watermarks, trace)?))
        }
        api::Request::WatermarksAll => {
            // lock-free like `Watermarks`: all five sets off the
            // published snapshots
            let sets = JobKind::all()
                .into_iter()
                .map(|job| {
                    let snap = shared.snapshot(job);
                    api::WatermarkSet {
                        job,
                        generation: snap.generation,
                        watermarks: snap.watermarks.clone(),
                    }
                })
                .collect();
            Ok(Response::WatermarksAll(sets))
        }
        api::Request::SyncPullAll { watermarks } => {
            // cross-job extraction in one round trip; shard locks are
            // taken one at a time, never nested
            let deltas = watermarks
                .iter()
                .map(|set| pull_delta(shared, set.job, &set.watermarks, trace))
                .collect::<Result<Vec<_>, _>>()?;
            Ok(Response::SyncDeltaAll(deltas))
        }
        api::Request::SyncPush { job, ops, snapshots } => {
            push_delta(shared, engine, job, &ops, &snapshots, trace).map(Response::SyncApplied)
        }
        api::Request::SyncPushAll { deltas } => {
            // one round trip applies every job's delta; shard locks are
            // taken one at a time, never nested
            let mut reports = Vec::with_capacity(deltas.len());
            for delta in &deltas {
                reports.push(push_delta(
                    shared,
                    engine,
                    delta.job,
                    &delta.ops,
                    &delta.snapshots,
                    trace,
                )?);
            }
            // post-apply marks (the acks a mesh sender records for this
            // deployment) — each push republished its snapshot above
            let watermarks = JobKind::all()
                .into_iter()
                .map(|job| {
                    let snap = shared.snapshot(job);
                    api::WatermarkSet {
                        job,
                        generation: snap.generation,
                        watermarks: snap.watermarks.clone(),
                    }
                })
                .collect();
            Ok(Response::SyncAppliedAll(api::SyncReportAll {
                reports,
                watermarks,
            }))
        }
        api::Request::MeshHello { hello } => {
            let mut local = Metrics::default();
            // roster surgery + floor computation under the mesh lock
            // (leaf class) only; the lock is dropped before any shard
            // lock is taken for truncation
            let (view, floors_by_job) = {
                let mut mesh = shared.mesh.lock_unpoisoned();
                let tick = hello.from.id == mesh.local().id;
                let evicted = mesh
                    .observe_hello(&hello)
                    .map_err(ApiError::InvalidRequest)?;
                local.mesh_hellos += 1;
                local.mesh_evictions += evicted;
                let floors: Vec<(JobKind, BTreeMap<String, u64>)> = if tick {
                    JobKind::all()
                        .into_iter()
                        .filter_map(|kind| {
                            let floors = mesh.acked_floors(kind);
                            (!floors.is_empty()).then_some((kind, floors))
                        })
                        .collect()
                } else {
                    Vec::new()
                };
                (mesh.view(), floors)
            };
            for (kind, floors) in floors_by_job {
                let shard_mutex = shard_for(shared, kind)?;
                let mut shard = {
                    let _lock_wait = trace.span(Stage::ShardLockWait);
                    shard_mutex.lock_unpoisoned()
                };
                let truncated = shard.truncate_to_floors(&floors)?;
                if truncated > 0 {
                    local.ops_truncated += truncated;
                    // republish so lock-free watermark reads see the
                    // raised floors
                    shared.publish(&shard);
                }
                drain_shard_stages(trace, &mut shard);
            }
            shared.metrics.lock_unpoisoned().fold(&local);
            Ok(Response::MeshView(view))
        }
        api::Request::MeshRoster => {
            Ok(Response::MeshView(shared.mesh.lock_unpoisoned().view()))
        }
        // Legacy (v2) federation, quarantined in `api::compat`: the
        // adapter translates the three v2 request shapes onto the
        // narrow host primitives implemented by `ServiceV2Host` below.
        v2 @ (api::Request::WatermarksV2 { .. }
        | api::Request::SyncPullV2 { .. }
        | api::Request::SyncPushV2 { .. }) => {
            let mut host = ServiceV2Host {
                shared,
                engine,
                trace,
            };
            compat::serve(&mut host, v2)
        }
        // Routed through their coalesced group paths by `worker_loop`;
        // landing here is a routing bug, answered with a typed error
        // instead of a worker-killing panic.
        api::Request::Recommend { .. } => Err(ApiError::Internal(
            "Recommend must be routed through serve_recommend_group".to_string(),
        )),
        api::Request::Submit { .. } => Err(ApiError::Internal(
            "Submit must be routed through serve_submit_group".to_string(),
        )),
    }
}

fn shard_for(shared: &Shared, kind: JobKind) -> Result<&Mutex<JobShard>, ApiError> {
    shared
        .shards
        .get(&kind)
        .ok_or_else(|| ApiError::Internal(format!("no shard for job {}", kind.name())))
}

/// Extract one job's record-level delta against a peer's op-log marks:
/// per-op suffixes where the logs are prefix-aligned above the
/// truncation floor, whole-org [`crate::repo::OrgSnapshot`] fallbacks
/// where the peer sits below it. Takes the shard lock (op logs aren't
/// in the published snapshot).
fn pull_delta(
    shared: &Shared,
    job: JobKind,
    theirs: &BTreeMap<String, crate::repo::OrgWatermark>,
    trace: &mut Trace,
) -> Result<api::SyncDelta, ApiError> {
    let shard_mutex = shard_for(shared, job)?;
    let shard = {
        let _lock_wait = trace.span(Stage::ShardLockWait);
        shard_mutex.lock_unpoisoned()
    };
    let plan = shard.repo().delta_plan(theirs);
    Ok(api::SyncDelta {
        job,
        generation: shard.generation(),
        ops: plan.ops,
        snapshots: plan.snapshots,
        watermarks: shard.repo().watermarks(),
    })
}

/// Apply one job's record-level delta under its shard lock: merge the
/// ops, adopt whole-org snapshot fallbacks, refresh the model, and
/// republish — the write half of `SyncPush` and (per job) of
/// `SyncPushAll`.
fn push_delta(
    shared: &Shared,
    engine: &mut dyn ModelTrainer,
    job: JobKind,
    ops: &[crate::repo::SyncOp],
    snapshots: &[crate::repo::OrgSnapshot],
    trace: &mut Trace,
) -> Result<api::SyncReport, ApiError> {
    api::validate_machines(&shared.cloud, ops.iter().map(|op| &op.record))?;
    for snap in snapshots {
        api::validate_machines(&shared.cloud, &snap.records)?;
    }
    let offered = ops.len() + snapshots.iter().map(|s| s.records.len()).sum::<usize>();
    let shard_mutex = shard_for(shared, job)?;
    let mut local = Metrics::default();
    let result = {
        let mut shard = {
            let _lock_wait = trace.span(Stage::ShardLockWait);
            shard_mutex.lock_unpoisoned()
        };
        let result = shard
            .apply_sync_ops(ops)
            .and_then(|mut outcome| {
                let (snap_outcome, snap_applied) = shard.apply_org_snapshots(snapshots)?;
                outcome.added += snap_outcome.added;
                outcome.replaced += snap_outcome.replaced;
                outcome.skipped += snap_outcome.skipped;
                outcome.conflicts.extend(snap_outcome.conflicts);
                outcome.logged.extend(snap_outcome.logged);
                Ok((outcome, snap_applied))
            })
            .and_then(|(outcome, snap_applied)| {
                shard.refresh_model(engine, &shared.cloud, &shared.policy, &mut local)?;
                shared.publish(&shard);
                local.sync_pushes += 1;
                local.sync_records_applied += outcome.changed() as u64;
                local.sync_conflicts += outcome.conflicts.len() as u64;
                let mut report = api::SyncReport::tally(
                    job,
                    offered,
                    outcome.added,
                    outcome.replaced,
                    outcome.conflicts,
                    &outcome.logged,
                    shard.generation(),
                );
                // adopted snapshot records fold into the prefix without
                // logged ops; credit their per-org applied counts here
                for (org, applied) in snap_applied {
                    *report.applied_by_org.entry(org).or_default() += applied;
                }
                Ok(report)
            });
        drain_shard_stages(trace, &mut shard);
        result
    };
    shared.metrics.lock_unpoisoned().fold(&local);
    result
}

/// The service's legacy (v2) host: hands [`compat::serve`] its three
/// primitives, each taking the target shard's lock exactly as the
/// retired inline arms did.
struct ServiceV2Host<'a> {
    shared: &'a Shared,
    engine: &'a mut dyn ModelTrainer,
    trace: &'a mut Trace,
}

impl V2Host for ServiceV2Host<'_> {
    fn v2_watermarks(&mut self, job: JobKind) -> Result<api::WatermarkSetV2, ApiError> {
        let shard_mutex = shard_for(self.shared, job)?;
        let shard = {
            let _lock_wait = self.trace.span(Stage::ShardLockWait);
            shard_mutex.lock_unpoisoned()
        };
        Ok(api::WatermarkSetV2 {
            job,
            generation: shard.generation(),
            watermarks: shard.repo().watermarks_v2(),
        })
    }

    fn v2_delta(
        &mut self,
        job: JobKind,
        theirs: &BTreeMap<String, OrgWatermarkV2>,
    ) -> Result<api::SyncDeltaV2, ApiError> {
        let shard_mutex = shard_for(self.shared, job)?;
        let shard = {
            let _lock_wait = self.trace.span(Stage::ShardLockWait);
            shard_mutex.lock_unpoisoned()
        };
        Ok(api::SyncDeltaV2 {
            job,
            generation: shard.generation(),
            records: shard.repo().delta_for_v2(theirs),
            watermarks: shard.repo().watermarks_v2(),
        })
    }

    fn v2_apply(
        &mut self,
        job: JobKind,
        records: Vec<RuntimeRecord>,
    ) -> Result<api::SyncReport, ApiError> {
        api::validate_machines(&self.shared.cloud, &records)?;
        let shard_mutex = shard_for(self.shared, job)?;
        let mut local = Metrics::default();
        let result = {
            let mut shard = {
                let _lock_wait = self.trace.span(Stage::ShardLockWait);
                shard_mutex.lock_unpoisoned()
            };
            let result = shard.apply_sync_records(&records).and_then(|outcome| {
                shard.refresh_model(
                    self.engine,
                    &self.shared.cloud,
                    &self.shared.policy,
                    &mut local,
                )?;
                self.shared.publish(&shard);
                local.sync_pushes += 1;
                local.sync_records_applied += outcome.changed() as u64;
                local.sync_conflicts += outcome.conflicts.len() as u64;
                Ok(api::SyncReport::tally(
                    job,
                    records.len(),
                    outcome.added,
                    outcome.replaced,
                    outcome.conflicts,
                    &outcome.applied,
                    shard.generation(),
                ))
            });
            drain_shard_stages(self.trace, &mut shard);
            result
        };
        self.shared.metrics.lock_unpoisoned().fold(&local);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_starts_and_shuts_down() {
        let service =
            CoordinatorService::spawn(Cloud::aws_like(), ServiceConfig::default().with_workers(2));
        let metrics = service.metrics().unwrap();
        assert_eq!(metrics.submissions, 0);
        service.shutdown();
    }

    #[test]
    fn client_outlives_service_with_clean_errors() {
        let service =
            CoordinatorService::spawn(Cloud::aws_like(), ServiceConfig::default().with_workers(1));
        let client = service.client();
        service.shutdown();
        let err = client.metrics();
        assert_eq!(err.unwrap_err(), ApiError::Stopped, "stopped service must error, not hang");
    }

    #[test]
    fn submit_without_data_takes_cold_start_path() {
        let service = CoordinatorService::spawn(
            Cloud::aws_like(),
            ServiceConfig::default().with_workers(2).with_seed(7),
        );
        let org = Organization::new("cold");
        let outcome = service.submit(&org, JobRequest::sort(12.0)).unwrap();
        assert!(outcome.model_used.is_none());
        let metrics = service.metrics().unwrap();
        assert_eq!(metrics.submissions, 1);
        assert_eq!(metrics.fallbacks, 1);
        assert_eq!(service.generation(JobKind::Sort), 1, "run was contributed");
        service.shutdown();
    }

    #[test]
    fn cold_recommend_is_a_typed_error() {
        let service = CoordinatorService::spawn(
            Cloud::aws_like(),
            ServiceConfig::default().with_workers(1).with_seed(8),
        );
        let err = service.recommend(JobRequest::sort(12.0)).unwrap_err();
        assert!(
            matches!(err, ApiError::ColdStart { job: JobKind::Sort, records: 0, .. }),
            "{err:?}"
        );
        service.shutdown();
    }

    #[test]
    fn single_reader_worker_steals_writes_and_serves_them() {
        let service = CoordinatorService::spawn(
            Cloud::aws_like(),
            ServiceConfig::default()
                .with_workers(1)
                .with_pjrt_workers(0)
                .with_seed(11),
        );
        // worker 0 is read-affine; the only way a submit gets served is
        // a cross-lane steal
        let outcome = service
            .submit(&Organization::new("o"), JobRequest::sort(12.0))
            .unwrap();
        assert_eq!(outcome.org, "o");
        let (reads_stolen, writes_stolen) = service.queue_steals();
        assert_eq!(reads_stolen, 0, "no write-affine worker exists");
        assert!(writes_stolen >= 1, "the read-affine worker must steal writes");
        service.shutdown();
    }

    #[test]
    fn shutdown_drains_accepted_requests_first() {
        let service = CoordinatorService::spawn(
            Cloud::aws_like(),
            ServiceConfig::default()
                .with_workers(2)
                .with_pjrt_workers(0)
                .with_seed(12),
        );
        let client = service.client();
        let org = Organization::new("o");
        let tickets: Vec<_> = (0..8)
            .map(|_| client.submit_nowait(&org, JobRequest::sort(12.0)).unwrap())
            .collect();
        // close() rejects new pushes but workers drain both lanes
        // before consuming their shutdown tokens
        service.shutdown();
        for ticket in tickets {
            ticket.wait().unwrap();
        }
    }

    #[test]
    fn invalid_requests_fail_fast_client_side() {
        let service = CoordinatorService::spawn(
            Cloud::aws_like(),
            ServiceConfig::default().with_workers(1).with_seed(9),
        );
        let client = service.client();
        let bad = JobRequest::sort(10.0).with_target_seconds(f64::NAN);
        assert!(matches!(
            client.submit(&Organization::new("o"), bad.clone()),
            Err(ApiError::InvalidRequest(_))
        ));
        assert!(matches!(
            client.submit_nowait(&Organization::new("o"), bad),
            Err(ApiError::InvalidRequest(_))
        ));
        assert_eq!(service.metrics().unwrap().submissions, 0);
        service.shutdown();
    }
}
