//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The real crate links `libxla_extension` and executes AOT-compiled HLO
//! through the PJRT C API. That shared library is not present in the
//! offline build environment, so this stub keeps the exact API surface
//! the workspace uses while reporting the runtime as unavailable:
//!
//! * [`PjRtClient::cpu`] returns an error, which `c3o::runtime::Runtime`
//!   surfaces at load time; the coordinator then falls back to the
//!   pure-Rust `models::native` engines.
//! * Every other method is reachable only behind a successfully
//!   constructed client, so they all return the same "unavailable" error
//!   (they exist purely so the call sites type-check).
//!
//! Replacing this path dependency with the real xla-rs crate re-enables
//! the PJRT path with no changes to the workspace code.

/// Error type mirroring xla-rs (call sites format it with `{:?}`).
#[derive(Debug, Clone)]
pub enum Error {
    /// The PJRT runtime is not available in this build.
    Unavailable(String),
}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error::Unavailable(format!(
        "{what}: PJRT runtime not available (offline xla stub; link the real xla-rs crate to enable)"
    )))
}

/// A PJRT client handle (CPU platform in the real crate).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    /// Construct the CPU PJRT client. Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }

    /// Compile a computation to a loaded executable.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }

    /// Upload a host buffer to the device.
    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

/// A device-resident buffer.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Fetch the buffer back to the host as a literal.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with host literals; returns per-device, per-output buffers.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    /// Execute with device-resident input buffers.
    pub fn execute_b<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// A host-side typed array (only f32 shapes are used by this workspace).
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1(xs: &[f32]) -> Literal {
        Literal {
            data: xs.to_vec(),
            dims: vec![xs.len() as i64],
        }
    }

    /// Scalar literal.
    pub fn scalar(x: f32) -> Literal {
        Literal {
            data: vec![x],
            dims: Vec::new(),
        }
    }

    /// Reshape to new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error::Unavailable(format!(
                "reshape: {} elements into dims {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Copy out as a typed vector (stub supports f32).
    pub fn to_vec<T: FromF32>(&self) -> Result<Vec<T>, Error> {
        Ok(self.data.iter().map(|&x| T::from_f32(x)).collect())
    }

    /// Decompose a tuple literal into its elements (unreachable in the
    /// stub: tuples only come back from executions, which always fail).
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable("Literal::to_tuple")
    }

    /// The literal's dimensions.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Conversion used by [`Literal::to_vec`].
pub trait FromF32 {
    fn from_f32(x: f32) -> Self;
}

impl FromF32 for f32 {
    fn from_f32(x: f32) -> Self {
        x
    }
}

impl FromF32 for f64 {
    fn from_f32(x: f32) -> Self {
        x as f64
    }
}

/// Parsed HLO module (text form in the real crate).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse an HLO text file. Always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping a parsed module.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
    }

    #[test]
    fn literals_are_host_side_and_work() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.dims(), &[2, 2]);
        assert!(l.reshape(&[3, 3]).is_err());
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }
}
