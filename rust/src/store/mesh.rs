//! Mesh membership and anti-entropy scheduling: the self-organizing
//! layer that replaces hand-wired peer lists.
//!
//! The paper's collaborative loop assumes organizations keep exchanging
//! runtime data indefinitely, without a central coordinator. This
//! module supplies the three pieces that makes that operational:
//!
//! * **Membership** — [`MeshState`], a roster of peers keyed by name
//!   with deterministic 64-bit IDs ([`peer_id`]). Peers join by
//!   helloing (or by being gossiped in another peer's
//!   [`MeshHello::known`] list), stay live by helloing again, and are
//!   evicted after missing [`MeshState::stale_after`] consecutive
//!   rounds. All iteration is over a `BTreeMap`, so every roster-driven
//!   decision is deterministic — the lint's `deterministic` zone rule.
//! * **Anti-entropy scheduling** — [`fanout_targets`] picks `k` peers
//!   per round by rotating a window over the name-sorted live roster,
//!   so every live peer is exchanged with at least once every
//!   `ceil(n/k)` rounds, deterministically. [`mesh_round`] runs one
//!   full tick against a local deployment: self-hello (advance the
//!   round, evict, re-evaluate truncation), then for each selected
//!   peer one gossip hello plus one **batched cross-job exchange**
//!   (`SyncPullAll`/`SyncPushAll` — all five [`JobKind`]s per round
//!   trip). [`MeshDriver`] runs those ticks on a background thread.
//! * **Ack tracking** — every hello carries the sender's own post-apply
//!   watermarks ([`MeshHello::acked`]); the receiver records them as
//!   "this peer holds at least these prefixes".
//!   [`MeshState::acked_floors`] folds them into the per-org acked
//!   floor — the highest seqno *every* live member has acknowledged —
//!   which the deployment feeds to
//!   [`RuntimeDataRepo::truncate_org_log`](crate::repo::RuntimeDataRepo::truncate_org_log):
//!   history below the floor is dropped from memory and folded into
//!   the store's base snapshot, so op-log memory is bounded by the
//!   *unacked suffix* instead of all history. A peer that falls below
//!   somebody's floor (or a fresh joiner) is healed by the whole-org
//!   [`OrgSnapshot`](crate::repo::OrgSnapshot) fallback of the v4
//!   delta plan.

use crate::api::{
    ApiError, Client, MeshHello, MeshPeer, MeshPeerStatus, MeshView, WatermarkSet,
};
use crate::util::hash::fnv1a64;
use crate::workloads::JobKind;
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

/// Deterministic peer identity: the FNV-1a hash of the peer's name.
/// Any two deployments derive the same ID for the same name, so a
/// forged or corrupted `(name, id)` pair is detectable without any
/// shared state.
pub fn peer_id(name: &str) -> u64 {
    fnv1a64(name.as_bytes())
}

/// Build the [`MeshPeer`] wire identity for `name`.
pub fn mesh_peer(name: &str) -> MeshPeer {
    MeshPeer {
        name: name.to_string(),
        id: peer_id(name),
    }
}

/// Rounds a member may miss before eviction, by default. With fanout-k
/// rotation a peer is contacted at least every `ceil(n/k)` rounds, so
/// the default tolerates meshes a few times larger than the fanout.
pub const DEFAULT_STALE_AFTER: u64 = 3;

/// One tracked roster member.
#[derive(Debug, Clone)]
struct MeshMember {
    peer: MeshPeer,
    /// Local round when this member last helloed (directly or via a
    /// relayed exchange); gossip-only members keep their join round.
    last_seen_round: u64,
    /// The member's post-apply watermarks per job — its acks.
    acked: Vec<WatermarkSet>,
}

/// A deployment's membership state: who it is, which round it is on,
/// and every peer it currently believes in. Owned by the deployment
/// (a plain field on the sequential coordinator, a leaf mutex in the
/// concurrent service) and mutated only through hellos.
#[derive(Debug, Clone)]
pub struct MeshState {
    local: MeshPeer,
    round: u64,
    stale_after: u64,
    /// Keyed by peer name — `BTreeMap` so every roster iteration
    /// (views, fanout, floor folds) is deterministic.
    members: BTreeMap<String, MeshMember>,
}

impl MeshState {
    /// A fresh mesh containing only the local deployment.
    pub fn new(name: &str) -> MeshState {
        MeshState {
            local: mesh_peer(name),
            round: 0,
            stale_after: DEFAULT_STALE_AFTER,
            members: BTreeMap::new(),
        }
    }

    /// Override how many rounds a member may miss before eviction.
    pub fn with_stale_after(mut self, rounds: u64) -> MeshState {
        self.stale_after = rounds.max(1);
        self
    }

    /// The local deployment's identity.
    pub fn local(&self) -> &MeshPeer {
        &self.local
    }

    /// The local anti-entropy round counter.
    pub fn round(&self) -> u64 {
        self.round
    }

    /// Rounds a member may miss before eviction.
    pub fn stale_after(&self) -> u64 {
        self.stale_after
    }

    fn is_live(&self, member: &MeshMember) -> bool {
        self.round.saturating_sub(member.last_seen_round) <= self.stale_after
    }

    /// Observe one hello. A *self*-hello (`from` = the local identity)
    /// is the anti-entropy tick: it advances the round and evicts stale
    /// members (returning how many). Any other hello marks the sender
    /// live at the current round and records its acks. Both kinds fold
    /// the sender's `known` gossip into the roster (new members join at
    /// the current round; existing members' liveness is *not* refreshed
    /// by gossip — only direct hellos count, so a dead peer cannot be
    /// kept alive by third parties re-gossiping it).
    ///
    /// Rejects hellos whose `(name, id)` pairs contradict [`peer_id`].
    pub fn observe_hello(&mut self, hello: &MeshHello) -> Result<u64, String> {
        let check = |p: &MeshPeer| -> Result<(), String> {
            if p.id == peer_id(&p.name) {
                Ok(())
            } else {
                Err(format!(
                    "peer {:?} claims id {:#x}, expected {:#x}",
                    p.name,
                    p.id,
                    peer_id(&p.name)
                ))
            }
        };
        check(&hello.from)?;
        for p in &hello.known {
            check(p)?;
        }
        for p in &hello.known {
            if p.id == self.local.id {
                continue;
            }
            self.members.entry(p.name.clone()).or_insert_with(|| MeshMember {
                peer: p.clone(),
                last_seen_round: self.round,
                acked: Vec::new(),
            });
        }
        if hello.from.id == self.local.id {
            // the local tick: advance, then cull members whose silence
            // crossed the staleness horizon
            self.round += 1;
            let before = self.members.len();
            let round = self.round;
            let stale_after = self.stale_after;
            self.members
                .retain(|_, m| round.saturating_sub(m.last_seen_round) <= stale_after);
            return Ok((before - self.members.len()) as u64);
        }
        let round = self.round;
        let member = self
            .members
            .entry(hello.from.name.clone())
            .or_insert_with(|| MeshMember {
                peer: hello.from.clone(),
                last_seen_round: round,
                acked: Vec::new(),
            });
        member.last_seen_round = round;
        if !hello.acked.is_empty() {
            member.acked = hello.acked.clone();
        }
        Ok(0)
    }

    /// Snapshot the roster (name-sorted, with liveness flags).
    pub fn view(&self) -> MeshView {
        MeshView {
            local: self.local.clone(),
            round: self.round,
            peers: self
                .members
                .values()
                .map(|m| MeshPeerStatus {
                    peer: m.peer.clone(),
                    last_seen_round: m.last_seen_round,
                    live: self.is_live(m),
                })
                .collect(),
        }
    }

    /// The per-org acked floor for `job`: the highest seqno every live
    /// member has acknowledged holding. An org any live member has no
    /// mark for floors at 0 (it cannot be truncated yet), and an empty
    /// live roster yields no floors at all — a deployment alone in the
    /// mesh never truncates, so late joiners still get full history
    /// served from ops rather than snapshot fallbacks.
    pub fn acked_floors(&self, job: JobKind) -> BTreeMap<String, u64> {
        let mut floors: Option<BTreeMap<String, u64>> = None;
        for m in self.members.values().filter(|m| self.is_live(m)) {
            // a member with no ack for this job pins every org at 0
            let Some(set) = m.acked.iter().find(|set| set.job == job) else {
                return BTreeMap::new();
            };
            let member: BTreeMap<String, u64> = set
                .watermarks
                .iter()
                .map(|(org, mark)| (org.clone(), mark.seqno))
                .collect();
            floors = Some(match floors {
                None => member,
                // fold by intersection: an org any member has never
                // heard of floors at 0, everything else at the minimum
                Some(acc) => acc
                    .into_iter()
                    .filter_map(|(org, floor)| {
                        member.get(&org).map(|theirs| (org, floor.min(*theirs)))
                    })
                    .collect(),
            });
        }
        let mut floors = floors.unwrap_or_default();
        floors.retain(|_, floor| *floor > 0);
        floors
    }
}

/// The deterministic fanout selection: filter the view to live peers
/// (name-sorted already) and rotate a `k`-wide window by the round
/// number, so consecutive rounds walk the whole roster.
pub fn fanout_targets(view: &MeshView, k: usize) -> Vec<MeshPeer> {
    let live: Vec<&MeshPeerStatus> = view.peers.iter().filter(|p| p.live).collect();
    let n = live.len();
    if n == 0 || k == 0 {
        return Vec::new();
    }
    let k = k.min(n);
    let start = (view.round as usize).wrapping_mul(k) % n;
    (0..k).map(|i| live[(start + i) % n].peer.clone()).collect()
}

/// What one [`mesh_round`] did, for logs, benches, and tests.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MeshRoundReport {
    /// The local round counter after the tick.
    pub round: u64,
    /// Names of the peers exchanged with this round.
    pub targets: Vec<String>,
    /// Requests sent to remote peers (the wire cost of the round —
    /// independent of the job-kind count, because the exchange is
    /// batched).
    pub peer_round_trips: u64,
    /// Holdings mutations applied, locally and at peers combined.
    pub changed: u64,
}

/// Run one anti-entropy tick for `local`: self-hello (advancing the
/// round, evicting stale members, re-evaluating acked-floor
/// truncation), then for each fanout-selected peer a gossip hello and
/// one batched cross-job exchange in each direction. Peers named in
/// the roster but absent from `peers` are skipped (they stale out and
/// are evicted after enough missed rounds).
pub fn mesh_round(
    local: &mut dyn Client,
    peers: &mut [(String, &mut dyn Client)],
    fanout: usize,
) -> Result<MeshRoundReport, ApiError> {
    let mut report = MeshRoundReport::default();

    // self-hello: our identity, our roster, our current acks
    let before = local.mesh_roster()?;
    let known: Vec<MeshPeer> = std::iter::once(before.local.clone())
        .chain(before.peers.iter().map(|p| p.peer.clone()))
        .collect();
    let mut acked = local.watermarks_all()?;
    let view = local.mesh_hello(MeshHello {
        from: before.local.clone(),
        known: known.clone(),
        acked: acked.clone(),
    })?;
    report.round = view.round;

    for target in fanout_targets(&view, fanout) {
        let Some((_, peer)) = peers.iter_mut().find(|(name, _)| *name == target.name)
        else {
            continue;
        };
        // 1 gossip: liveness + roster + our acks, one round trip
        peer.mesh_hello(MeshHello {
            from: view.local.clone(),
            known: known.clone(),
            acked: acked.clone(),
        })?;
        // pull direction: their cross-job delta against our marks,
        // applied locally (2 round trips)
        let deltas = peer.sync_pull_all(acked.clone())?;
        let applied = local.sync_push_all(deltas)?;
        report.changed += applied
            .reports
            .iter()
            .map(|r| r.changed() as u64)
            .sum::<u64>();
        // our acks moved; later targets and the push-back must see the
        // post-apply positions
        acked = applied.watermarks;
        // push direction: our cross-job delta against their marks
        // (1 round trip for the marks, 1 for the push)
        let their_marks = peer.watermarks_all()?;
        let deltas = local.sync_pull_all(their_marks)?;
        let pushed = peer.sync_push_all(deltas)?;
        report.changed += pushed
            .reports
            .iter()
            .map(|r| r.changed() as u64)
            .sum::<u64>();
        // relay the peer's post-apply acks into our roster: it is
        // live (it just answered) and holds at least these prefixes
        local.mesh_hello(MeshHello {
            from: target.clone(),
            known: Vec::new(),
            acked: pushed.watermarks,
        })?;
        report.peer_round_trips += 4;
        report.targets.push(target.name);
    }
    Ok(report)
}

/// Background anti-entropy: [`mesh_round`] on a fixed interval until
/// the driver is dropped (or a deployment reports
/// [`ApiError::Stopped`]). The mesh-membership replacement for the
/// static-peer-list `SyncDriver` loop.
pub struct MeshDriver {
    stop: Option<mpsc::Sender<()>>,
    handle: Option<thread::JoinHandle<Vec<MeshRoundReport>>>,
}

impl MeshDriver {
    /// Spawn the loop: one round immediately, then one per `interval`.
    /// `local` is the deployment this driver ticks; `peers` are the
    /// reachable remote deployments by mesh name.
    pub fn spawn<L, P>(
        mut local: L,
        mut peers: Vec<(String, P)>,
        fanout: usize,
        interval: Duration,
    ) -> MeshDriver
    where
        L: Client + Send + 'static,
        P: Client + Send + 'static,
    {
        let (stop, stopped) = mpsc::channel::<()>();
        let handle = thread::spawn(move || {
            let mut reports = Vec::new();
            loop {
                let mut refs: Vec<(String, &mut dyn Client)> = peers
                    .iter_mut()
                    .map(|(name, client)| (name.clone(), client as &mut dyn Client))
                    .collect();
                match mesh_round(&mut local, &mut refs, fanout) {
                    Ok(report) => reports.push(report),
                    // a deployment shut down: the mesh loop is over
                    Err(ApiError::Stopped) => return reports,
                    // transient failure (e.g. a store hiccup): skip the
                    // round; anti-entropy retries by construction
                    Err(_) => {}
                }
                match stopped.recv_timeout(interval) {
                    Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => return reports,
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                }
            }
        });
        MeshDriver {
            stop: Some(stop),
            handle: Some(handle),
        }
    }

    /// Stop the loop and collect every round's report.
    pub fn stop(mut self) -> Vec<MeshRoundReport> {
        self.shutdown()
    }

    fn shutdown(&mut self) -> Vec<MeshRoundReport> {
        if let Some(stop) = self.stop.take() {
            let _ = stop.send(());
        }
        match self.handle.take() {
            Some(handle) => handle.join().unwrap_or_default(),
            None => Vec::new(),
        }
    }
}

impl Drop for MeshDriver {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo::OrgWatermark;

    fn hello_from(name: &str, known: &[&str]) -> MeshHello {
        MeshHello {
            from: mesh_peer(name),
            known: known.iter().map(|n| mesh_peer(n)).collect(),
            acked: Vec::new(),
        }
    }

    fn acked_set(job: JobKind, marks: &[(&str, u64)]) -> WatermarkSet {
        WatermarkSet {
            job,
            generation: 0,
            watermarks: marks
                .iter()
                .map(|(org, seqno)| {
                    (
                        org.to_string(),
                        OrgWatermark {
                            seqno: *seqno,
                            digest: 0,
                            floor: 0,
                        },
                    )
                })
                .collect(),
        }
    }

    #[test]
    fn peer_ids_are_deterministic_and_distinct() {
        assert_eq!(peer_id("org-a"), peer_id("org-a"));
        assert_ne!(peer_id("org-a"), peer_id("org-b"));
        assert_eq!(mesh_peer("org-a").id, peer_id("org-a"));
    }

    #[test]
    fn forged_peer_ids_are_rejected() {
        let mut mesh = MeshState::new("local");
        let mut hello = hello_from("imposter", &[]);
        hello.from.id ^= 1;
        assert!(mesh.observe_hello(&hello).is_err());
        let mut hello = hello_from("honest", &["gossiped"]);
        hello.known[0].id ^= 1;
        assert!(mesh.observe_hello(&hello).is_err());
    }

    #[test]
    fn membership_lifecycle_join_refresh_evict() {
        let mut mesh = MeshState::new("local").with_stale_after(2);
        mesh.observe_hello(&hello_from("a", &["a", "b"])).unwrap();
        let view = mesh.view();
        assert_eq!(
            view.peers.iter().map(|p| p.peer.name.as_str()).collect::<Vec<_>>(),
            vec!["a", "b"],
            "direct sender and gossiped member both join, sorted"
        );
        assert!(view.peers.iter().all(|p| p.live));

        // "a" keeps helloing, "b" goes silent: after stale_after missed
        // rounds the tick evicts "b" and only "b"
        let mut evicted_total = 0;
        for _ in 0..3 {
            evicted_total += mesh
                .observe_hello(&hello_from("local", &["local", "a", "b"]))
                .unwrap();
            mesh.observe_hello(&hello_from("a", &["a"])).unwrap();
        }
        assert_eq!(evicted_total, 1, "exactly the silent member evicted");
        let names: Vec<&str> =
            mesh.view().peers.iter().map(|p| p.peer.name.as_str()).collect();
        assert_eq!(names, vec!["a"]);
        assert_eq!(mesh.round(), 3, "each self-hello advanced the round");

        // gossip alone cannot resurrect liveness: "a" re-gossips "b",
        // which rejoins as a member but stales out again without ever
        // helloing directly
        mesh.observe_hello(&hello_from("a", &["a", "b"])).unwrap();
        assert_eq!(mesh.view().peers.len(), 2);
        for _ in 0..3 {
            mesh.observe_hello(&hello_from("local", &["local"])).unwrap();
            mesh.observe_hello(&hello_from("a", &["a"])).unwrap();
        }
        let names: Vec<&str> =
            mesh.view().peers.iter().map(|p| p.peer.name.as_str()).collect();
        assert_eq!(names, vec!["a"], "gossip-only member evicted again");
    }

    #[test]
    fn fanout_rotation_covers_the_roster_deterministically() {
        let mut mesh = MeshState::new("local");
        for name in ["a", "b", "c", "d", "e"] {
            mesh.observe_hello(&hello_from(name, &[])).unwrap();
        }
        // the same view always selects the same targets
        assert_eq!(fanout_targets(&mesh.view(), 2), fanout_targets(&mesh.view(), 2));
        // across ceil(5/2) + extra rounds, every peer is selected
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..5 {
            for p in fanout_targets(&mesh.view(), 2) {
                seen.insert(p.name);
            }
            // keep everyone live while the window rotates
            mesh.observe_hello(&hello_from("local", &[])).unwrap();
            for name in ["a", "b", "c", "d", "e"] {
                mesh.observe_hello(&hello_from(name, &[])).unwrap();
            }
        }
        assert_eq!(seen.len(), 5, "rotation reached every live peer");
        // fanout larger than the roster clamps; an empty roster yields
        // no targets
        assert_eq!(fanout_targets(&mesh.view(), 99).len(), 5);
        assert!(fanout_targets(&MeshState::new("solo").view(), 3).is_empty());
    }

    #[test]
    fn acked_floors_take_the_minimum_over_live_members() {
        let mut mesh = MeshState::new("local");
        assert!(
            mesh.acked_floors(JobKind::Sort).is_empty(),
            "an empty mesh never truncates"
        );

        let mut a = hello_from("a", &[]);
        a.acked = vec![acked_set(JobKind::Sort, &[("x", 5), ("y", 2)])];
        mesh.observe_hello(&a).unwrap();
        let mut b = hello_from("b", &[]);
        b.acked = vec![acked_set(JobKind::Sort, &[("x", 3)])];
        mesh.observe_hello(&b).unwrap();

        let floors = mesh.acked_floors(JobKind::Sort);
        assert_eq!(floors.get("x"), Some(&3), "minimum across members");
        assert_eq!(floors.get("y"), None, "org unknown to b floors at 0");
        assert!(
            mesh.acked_floors(JobKind::Grep).is_empty(),
            "a job nobody acked cannot truncate"
        );

        // once "b" stales out, only "a"'s acks bound the floor
        let mut mesh = mesh.with_stale_after(1);
        mesh.observe_hello(&hello_from("local", &[])).unwrap();
        let mut a = hello_from("a", &[]);
        a.acked = vec![acked_set(JobKind::Sort, &[("x", 5), ("y", 2)])];
        mesh.observe_hello(&a).unwrap();
        let evicted = mesh.observe_hello(&hello_from("local", &[])).unwrap();
        assert_eq!(evicted, 1, "b missed too many rounds");
        let floors = mesh.acked_floors(JobKind::Sort);
        assert_eq!(floors.get("x"), Some(&5));
        assert_eq!(floors.get("y"), Some(&2));
    }
}
