//! Distributed-dataflow execution simulator — the Spark-on-EMR substrate.
//!
//! The paper's 930 experiments run five Spark jobs on real EMR clusters;
//! this module is the synthetic equivalent. A job is a DAG of
//! [`Stage`]s (see [`stage`]); the [`engine`] executes the stages on a
//! simulated [`crate::cloud::Cluster`], modeling:
//!
//! * **wave scheduling** — tasks are placed into `nodes × vcpus` slots;
//!   a stage runs in `ceil(tasks / slots)` waves;
//! * **resource phases** — per-task CPU work, disk reads/writes, and
//!   all-to-all shuffle traffic, each bound by the corresponding machine
//!   bandwidth from the catalog;
//! * **the memory/spill model** — when a stage's working set per node
//!   exceeds the executor memory, the overflow spills: extra disk traffic
//!   plus recomputation penalty. This is the mechanism behind the paper's
//!   Fig. 3/6 observation that SGD and K-Means see *super-linear* speedup
//!   from scale-out 2 to 4 (the bottleneck disappears);
//! * **fixed overheads** — per-job startup and per-stage scheduling
//!   barriers, which are what makes small-input jobs (PageRank on
//!   130–440 MB graphs) benefit little from scale-out (Fig. 6);
//! * **variance** — seeded log-normal noise per stage wave, so repeated
//!   runs differ like real clusters and the median-of-five protocol of
//!   the paper is meaningful.
//!
//! The simulator is deterministic given a seed: the whole corpus can be
//! regenerated bit-for-bit.

pub mod engine;
pub mod stage;

pub use engine::{SimConfig, SimulationResult, Simulator, StageReport};
pub use stage::{Stage, StageKind};
