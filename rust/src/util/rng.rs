//! Deterministic pseudo-random number generation.
//!
//! A PCG32 (XSH-RR 64/32) generator: small state, good statistical quality,
//! and fully deterministic across platforms — every experiment in the corpus
//! and every property-test case is reproducible from a `u64` seed.

/// Permuted congruential generator, 64-bit state / 32-bit output (XSH-RR).
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id.
    ///
    /// Distinct stream ids yield independent sequences for the same seed,
    /// which the simulator uses to decorrelate e.g. task-time noise from
    /// straggler injection.
    pub fn new_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Create a generator from a seed on the default stream.
    pub fn new(seed: u64) -> Self {
        Self::new_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output (two 32-bit draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` using Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_wide(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; the spare is
    /// discarded to keep the call sequence position-independent).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Log-normal multiplicative noise with median 1.0 and the given sigma
    /// of the underlying normal. Used for runtime variance: multiplicative,
    /// right-skewed, median-preserving — matching how the paper controls
    /// outliers by reporting the median of five repetitions.
    #[inline]
    pub fn lognormal_noise(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }

    /// Sample from a Gamma(shape k, scale θ) — Marsaglia–Tsang.
    pub fn gamma(&mut self, k: f64, theta: f64) -> f64 {
        if k < 1.0 {
            let u = self.f64().max(1e-300);
            return self.gamma(k + 1.0, theta) * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v * theta;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn choose_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Derive an independent child generator (for parallel workers).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        Pcg32::new_stream(self.next_u64() ^ tag.wrapping_mul(PCG_MULT), tag | 1)
    }
}

#[inline]
fn mul_wide(a: u64, b: u64) -> (u64, u64) {
    let r = (a as u128) * (b as u128);
    ((r >> 64) as u64, r as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(7);
        let mut b = Pcg32::new(7);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg32::new_stream(7, 1);
        let mut b = Pcg32::new_stream(7, 2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3, "streams should differ, {same} collisions");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg32::new(42);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_is_half() {
        let mut rng = Pcg32::new(1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_over_range() {
        let mut rng = Pcg32::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn lognormal_noise_median_one() {
        let mut rng = Pcg32::new(5);
        let mut xs: Vec<f64> = (0..50_001).map(|_| rng.lognormal_noise(0.3)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[25_000];
        assert!((median - 1.0).abs() < 0.02, "median {median}");
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn gamma_mean() {
        let mut rng = Pcg32::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.gamma(2.0, 3.0)).sum::<f64>() / n as f64;
        assert!((mean - 6.0).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(17);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn choose_indices_distinct() {
        let mut rng = Pcg32::new(23);
        let idx = rng.choose_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }

    #[test]
    fn choose_indices_k_larger_than_n() {
        let mut rng = Pcg32::new(23);
        let idx = rng.choose_indices(5, 20);
        assert_eq!(idx.len(), 5);
    }

    #[test]
    fn fork_diverges_from_parent() {
        let mut parent = Pcg32::new(31);
        let mut child = parent.fork(1);
        let mut parent2 = Pcg32::new(31);
        let mut child2 = parent2.fork(1);
        // forks are deterministic...
        for _ in 0..100 {
            assert_eq!(child.next_u32(), child2.next_u32());
        }
        // ...and differ from a differently tagged fork
        let mut parent3 = Pcg32::new(31);
        let mut child3 = parent3.fork(2);
        let mut child_r = Pcg32::new(31);
        let mut child_r = child_r.fork(1);
        let same = (0..100)
            .filter(|_| child3.next_u32() == child_r.next_u32())
            .count();
        assert!(same < 3);
    }
}
