//! Stage model: the unit of simulated execution.
//!
//! A [`Stage`] describes the aggregate resource demands of one Spark-like
//! stage (a set of tasks between shuffle boundaries). Workloads
//! (`crate::workloads`) compile a job specification into a `Vec<Stage>`;
//! the engine executes them in order with a barrier between stages, as
//! Spark's scheduler does.

/// What kind of stage this is, for reporting and for the engine's
/// parallelism rules.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageKind {
    /// Embarrassingly parallel over input partitions (map/scan).
    Parallel,
    /// All-to-all shuffle boundary (sort exchange, groupBy, join).
    Shuffle,
    /// Iterative superstep (one iteration of SGD/K-Means/PageRank);
    /// scheduled like `Parallel` but annotated for reports.
    Iteration,
    /// Serial section — runs on a single node regardless of cluster size
    /// (e.g. Grep writing matched lines back in original order, driver
    /// aggregation). The Amdahl term behind Fig. 7.
    Serial,
}

/// Aggregate resource demands of one stage.
///
/// All quantities are *totals across the stage*, not per task: the engine
/// divides by cluster parallelism. CPU work is in "normalized core
/// seconds" (time on one `cpu_perf = 1.0` vCPU).
#[derive(Debug, Clone)]
pub struct Stage {
    /// Human-readable stage label, e.g. `"sort:exchange"`.
    pub name: String,
    pub kind: StageKind,
    /// Number of tasks (partitions). The engine schedules these in waves.
    pub tasks: u32,
    /// Total CPU work, normalized core-seconds.
    pub cpu_core_s: f64,
    /// Total bytes read from local disk / object store, MB.
    pub disk_read_mb: f64,
    /// Total bytes written to local disk / object store, MB.
    pub disk_write_mb: f64,
    /// Total bytes exchanged over the network in an all-to-all shuffle, MB.
    /// The engine scales effective traffic by `(n-1)/n` (local fraction
    /// stays on-node).
    pub shuffle_mb: f64,
    /// Working set that must be memory-resident *across the whole cluster*
    /// during this stage, MB (e.g. the cached training set for SGD).
    /// Exceeding per-node executor memory triggers the spill model.
    pub mem_working_set_mb: f64,
    /// Fraction of this stage's task time that is pipelined with I/O
    /// (0 = strictly sequential phases, 1 = perfectly overlapped).
    pub overlap: f64,
}

impl Stage {
    /// A parallel scan stage with sensible defaults (no shuffle, no
    /// working set, moderate overlap).
    pub fn parallel(name: &str, tasks: u32) -> Self {
        Stage {
            name: name.to_string(),
            kind: StageKind::Parallel,
            tasks,
            cpu_core_s: 0.0,
            disk_read_mb: 0.0,
            disk_write_mb: 0.0,
            shuffle_mb: 0.0,
            mem_working_set_mb: 0.0,
            overlap: 0.7,
        }
    }

    /// A shuffle stage.
    pub fn shuffle(name: &str, tasks: u32) -> Self {
        Stage {
            kind: StageKind::Shuffle,
            ..Stage::parallel(name, tasks)
        }
    }

    /// An iteration superstep.
    pub fn iteration(name: &str, tasks: u32) -> Self {
        Stage {
            kind: StageKind::Iteration,
            ..Stage::parallel(name, tasks)
        }
    }

    /// A serial (single-node) stage.
    pub fn serial(name: &str) -> Self {
        Stage {
            kind: StageKind::Serial,
            overlap: 0.0,
            ..Stage::parallel(name, 1)
        }
    }

    pub fn with_cpu(mut self, core_s: f64) -> Self {
        self.cpu_core_s = core_s;
        self
    }

    pub fn with_disk(mut self, read_mb: f64, write_mb: f64) -> Self {
        self.disk_read_mb = read_mb;
        self.disk_write_mb = write_mb;
        self
    }

    pub fn with_shuffle(mut self, mb: f64) -> Self {
        self.shuffle_mb = mb;
        self
    }

    pub fn with_working_set(mut self, mb: f64) -> Self {
        self.mem_working_set_mb = mb;
        self
    }

    pub fn with_overlap(mut self, overlap: f64) -> Self {
        assert!((0.0..=1.0).contains(&overlap));
        self.overlap = overlap;
        self
    }

    /// Sanity: all demands non-negative and tasks > 0.
    pub fn validate(&self) -> Result<(), String> {
        if self.tasks == 0 {
            return Err(format!("stage {}: zero tasks", self.name));
        }
        for (label, v) in [
            ("cpu", self.cpu_core_s),
            ("disk_read", self.disk_read_mb),
            ("disk_write", self.disk_write_mb),
            ("shuffle", self.shuffle_mb),
            ("working_set", self.mem_working_set_mb),
        ] {
            if !v.is_finite() || v < 0.0 {
                return Err(format!("stage {}: bad {label} = {v}", self.name));
            }
        }
        if self.kind == StageKind::Serial && self.tasks != 1 {
            return Err(format!("stage {}: serial stage must have 1 task", self.name));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_compose() {
        let s = Stage::parallel("scan", 64)
            .with_cpu(100.0)
            .with_disk(1000.0, 0.0)
            .with_shuffle(500.0)
            .with_working_set(2000.0)
            .with_overlap(0.5);
        assert_eq!(s.tasks, 64);
        assert_eq!(s.cpu_core_s, 100.0);
        assert_eq!(s.disk_read_mb, 1000.0);
        assert_eq!(s.shuffle_mb, 500.0);
        assert_eq!(s.mem_working_set_mb, 2000.0);
        assert_eq!(s.overlap, 0.5);
        assert!(s.validate().is_ok());
    }

    #[test]
    fn serial_stage_single_task() {
        let s = Stage::serial("write_matches");
        assert_eq!(s.tasks, 1);
        assert!(s.validate().is_ok());
        let bad = Stage {
            tasks: 4,
            ..Stage::serial("oops")
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn validate_rejects_negative() {
        let s = Stage::parallel("x", 1).with_cpu(-1.0);
        assert!(s.validate().is_err());
        let s = Stage {
            shuffle_mb: f64::NAN,
            ..Stage::parallel("y", 1)
        };
        assert!(s.validate().is_err());
    }

    #[test]
    #[should_panic]
    fn overlap_out_of_range_panics() {
        Stage::parallel("x", 1).with_overlap(1.5);
    }
}
