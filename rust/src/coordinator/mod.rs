//! The collaboration coordinator — the C3O system runtime (paper Fig. 1/2).
//!
//! Owns the full loop for every participating organization:
//!
//! 1. a user submits a job (dataset characteristics, parameters, runtime
//!    target);
//! 2. the coordinator ensures a fresh prediction model for that job —
//!    **dynamic model selection** (§V-C) retrains and re-selects between
//!    the pessimistic and optimistic families whenever enough new shared
//!    data arrived since the last training;
//! 3. the **cluster configurator** picks the cheapest configuration
//!    predicted to meet the target;
//! 4. the **cloud access manager** provisions the cluster (paying the
//!    EMR-like delay) and runs the job on the dataflow simulator;
//! 5. the measured runtime is contributed back to the shared
//!    **runtime data repository**, closing the collaborative loop.
//!
//! When a job's repository is too small to train on, the coordinator
//! falls back to conservative overprovisioning (and the run it contributes
//! shrinks that cold-start window for everyone). When a repository
//! outgrows the kNN artifact capacity, it trains on a coverage-preserving
//! sample (§III-C).
//!
//! [`session`] wraps the coordinator in a dedicated worker thread behind
//! std channels — the event-loop deployment shape (tokio is not in the
//! offline vendor set; a thread + channel loop is the same architecture).

pub mod session;

use crate::baselines::{ConfigSearch, NaiveMax};
use crate::cloud::Cloud;
use crate::configurator::{ClusterChoice, Configurator, JobRequest};
use crate::models::oracle::SimOracle;
use crate::models::selection::{select_and_train, SelectionReport};
use crate::models::{BoundModel, ModelKind, Predictor};
use crate::repo::sampling::sampled_repo;
use crate::repo::{RuntimeDataRepo, RuntimeRecord};
use crate::util::rng::Pcg32;
use crate::workloads::JobKind;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// A participating organization (provenance + its usual submission niche).
#[derive(Debug, Clone, PartialEq)]
pub struct Organization {
    pub name: String,
}

impl Organization {
    pub fn new(name: &str) -> Self {
        Organization {
            name: name.to_string(),
        }
    }
}

/// The outcome of one submitted job, end to end.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub org: String,
    pub job: JobKind,
    /// The configuration decision (None when the cold-start fallback ran).
    pub choice: Option<ClusterChoice>,
    pub machine: String,
    pub scaleout: u32,
    pub model_used: Option<ModelKind>,
    pub predicted_runtime_s: f64,
    pub actual_runtime_s: f64,
    /// Cluster cost of the actual run (incl. provisioning).
    pub actual_cost_usd: f64,
    pub provisioning_s: f64,
    pub target_s: Option<f64>,
    pub met_target: bool,
}

impl JobOutcome {
    /// Absolute percentage error of the runtime prediction (NaN for
    /// fallback runs without a prediction).
    pub fn prediction_error_pct(&self) -> f64 {
        if self.predicted_runtime_s.is_nan() {
            f64::NAN
        } else {
            100.0 * ((self.predicted_runtime_s - self.actual_runtime_s) / self.actual_runtime_s).abs()
        }
    }
}

/// Aggregate coordinator metrics.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub submissions: u64,
    pub fallbacks: u64,
    pub retrains: u64,
    pub targets_given: u64,
    pub targets_met: u64,
    pub total_cost_usd: f64,
    /// Sum + count of absolute percentage errors (model-served runs).
    pub ape_sum: f64,
    pub ape_count: u64,
}

impl Metrics {
    pub fn mean_prediction_error_pct(&self) -> f64 {
        if self.ape_count == 0 {
            f64::NAN
        } else {
            self.ape_sum / self.ape_count as f64
        }
    }

    pub fn target_hit_rate(&self) -> f64 {
        if self.targets_given == 0 {
            f64::NAN
        } else {
            self.targets_met as f64 / self.targets_given as f64
        }
    }
}

struct JobModel {
    trained_at_version: u64,
    model: crate::models::TrainedModel,
    report: SelectionReport,
}

/// The C3O coordinator.
pub struct Coordinator {
    cloud: Cloud,
    predictor: Predictor,
    repos: HashMap<JobKind, RuntimeDataRepo>,
    models: HashMap<JobKind, JobModel>,
    /// Retrain when this many records arrived since the last training.
    pub retrain_every: u64,
    /// Minimum records before the model path activates (cold-start
    /// threshold).
    pub min_records: usize,
    /// CV folds for dynamic selection.
    pub cv_folds: usize,
    metrics: Metrics,
    rng: Pcg32,
}

impl Coordinator {
    /// Build a coordinator over a cloud and an artifacts directory.
    pub fn new(cloud: Cloud, artifacts_dir: &Path, seed: u64) -> Result<Coordinator> {
        let predictor = Predictor::new(artifacts_dir).context("loading PJRT predictor")?;
        Ok(Coordinator {
            cloud,
            predictor,
            repos: HashMap::new(),
            models: HashMap::new(),
            retrain_every: 12,
            min_records: 12,
            cv_folds: 4,
            metrics: Metrics::default(),
            rng: Pcg32::new(seed),
        })
    }

    pub fn cloud(&self) -> &Cloud {
        &self.cloud
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The shared repository for a job (empty if nothing shared yet).
    pub fn repo(&self, job: JobKind) -> Option<&RuntimeDataRepo> {
        self.repos.get(&job)
    }

    /// Latest selection report for a job's model, if trained.
    pub fn selection_report(&self, job: JobKind) -> Option<&SelectionReport> {
        self.models.get(&job).map(|m| &m.report)
    }

    /// Merge externally shared data (e.g. the public corpus) into the
    /// job's repository — "users can contribute their generated runtime
    /// data" (§III-A). Returns records actually added.
    pub fn share(&mut self, repo: &RuntimeDataRepo) -> Result<usize> {
        let entry = self
            .repos
            .entry(repo.job())
            .or_insert_with(|| RuntimeDataRepo::new(repo.job()));
        entry.merge(repo).map_err(anyhow::Error::msg)
    }

    /// Ensure the job's model is fresh; retrain via dynamic selection if
    /// the repo advanced by `retrain_every` since the last training.
    fn ensure_model(&mut self, job: JobKind) -> Result<Option<ModelKind>> {
        let Some(repo) = self.repos.get(&job) else {
            return Ok(None);
        };
        if repo.len() < self.min_records {
            return Ok(None);
        }
        let version = repo.version();
        let stale = match self.models.get(&job) {
            None => true,
            Some(m) => version.saturating_sub(m.trained_at_version) >= self.retrain_every,
        };
        if stale {
            // cap training set at the kNN artifact capacity via coverage
            // sampling (§III-C)
            let cap = self.predictor.runtime().manifest().knn_train_rows;
            let train_repo = if repo.len() > cap {
                sampled_repo(repo, &self.cloud, cap)
            } else {
                repo.clone()
            };
            let (model, report) = select_and_train(
                &mut self.predictor,
                &self.cloud,
                &train_repo,
                self.cv_folds,
                version,
            )?;
            self.models.insert(
                job,
                JobModel {
                    trained_at_version: version,
                    model,
                    report,
                },
            );
            self.metrics.retrains += 1;
        }
        Ok(self.models.get(&job).map(|m| m.model.kind))
    }

    /// Full submission loop for one job request.
    pub fn submit(&mut self, org: &Organization, request: &JobRequest) -> Result<JobOutcome> {
        let job = request.kind();
        let model_used = self.ensure_model(job)?;

        // 1) decide a configuration
        let (machine, scaleout, predicted, choice) = match model_used {
            Some(_) => {
                let jm = self.models.get(&job).expect("ensured");
                // candidates only over machine types present in the
                // shared data: the models interpolate, they don't leap
                // across unmeasured memory configurations
                let observed: std::collections::BTreeSet<String> = self.repos[&job]
                    .records()
                    .iter()
                    .map(|r| r.machine.clone())
                    .collect();
                let mut bound = BoundModel {
                    predictor: &mut self.predictor,
                    model: jm.model.clone(),
                };
                let configurator = Configurator::new(&self.cloud)
                    .with_machines(observed.into_iter().collect());
                let choice = configurator
                    .configure(&mut bound, request)?
                    .context("empty catalog")?;
                (
                    choice.machine_type.clone(),
                    choice.node_count,
                    choice.predicted_runtime_s,
                    Some(choice),
                )
            }
            None => {
                // cold start: conservative overprovisioning
                let mut oracle = SimOracle::new(job, self.rng.next_u64());
                let out = NaiveMax::default().search(&self.cloud, &mut oracle, request)?;
                self.metrics.fallbacks += 1;
                (out.machine, out.scaleout, f64::NAN, None)
            }
        };

        // 2) provision + run (the cloud access manager step)
        let mut cluster = self
            .cloud
            .provision(&machine, scaleout, &mut self.rng);
        cluster.mark_running();
        let spec_stages = request.spec.stages();
        let mt = self.cloud.machine(&machine).expect("catalog");
        let sim = crate::sim::Simulator::default();
        let mut run_rng = self.rng.fork(0xEC);
        let actual = sim.run(mt, scaleout, &spec_stages, &mut run_rng).runtime_s;
        cluster.record_busy(actual);
        let held = cluster.terminate();
        let cost = self.cloud.cost_usd(&machine, scaleout, held);

        // 3) contribute the new record to the shared repository
        let record = RuntimeRecord {
            job,
            org: org.name.clone(),
            machine: machine.clone(),
            scaleout,
            job_features: request.spec.job_features(),
            runtime_s: actual,
        };
        let entry = self
            .repos
            .entry(job)
            .or_insert_with(|| RuntimeDataRepo::new(job));
        // duplicate configs are fine at contribution time; merge-level
        // dedup happens when repos are exchanged between parties
        entry.contribute(record).map_err(anyhow::Error::msg)?;

        // 4) metrics
        let met_target = request.target_s.map_or(true, |t| actual <= t);
        self.metrics.submissions += 1;
        self.metrics.total_cost_usd += cost;
        if request.target_s.is_some() {
            self.metrics.targets_given += 1;
            if met_target {
                self.metrics.targets_met += 1;
            }
        }
        let outcome = JobOutcome {
            org: org.name.clone(),
            job,
            choice,
            machine,
            scaleout,
            model_used,
            predicted_runtime_s: predicted,
            actual_runtime_s: actual,
            actual_cost_usd: cost,
            provisioning_s: cluster.provisioning_delay_s(),
            target_s: request.target_s,
            met_target,
        };
        if !outcome.prediction_error_pct().is_nan() {
            self.metrics.ape_sum += outcome.prediction_error_pct();
            self.metrics.ape_count += 1;
        }
        Ok(outcome)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use crate::workloads::ExperimentGrid;

    fn corpus_repo(cloud: &Cloud, kind: JobKind) -> RuntimeDataRepo {
        let grid = ExperimentGrid {
            experiments: ExperimentGrid::paper_table1()
                .experiments
                .into_iter()
                .filter(|e| e.spec.kind() == kind)
                .collect(),
            repetitions: 3,
        };
        grid.execute(cloud, 21).repo_for(kind)
    }

    macro_rules! require_artifacts {
        () => {{
            let dir = Runtime::default_dir();
            if !Runtime::artifacts_available(&dir) {
                eprintln!("SKIP: artifacts not built");
                return;
            }
            dir
        }};
    }

    #[test]
    fn cold_start_falls_back_then_model_takes_over() {
        let dir = require_artifacts!();
        let cloud = Cloud::aws_like();
        let mut coord = Coordinator::new(cloud, &dir, 1).unwrap();
        coord.min_records = 5;
        coord.retrain_every = 5;
        let org = Organization::new("lab-a");
        // no shared data yet: fallback
        let o1 = coord.submit(&org, &JobRequest::sort(12.0)).unwrap();
        assert!(o1.model_used.is_none());
        assert_eq!(coord.metrics().fallbacks, 1);
        // a few more submissions build up the repo
        for gb in [10.0, 14.0, 16.0, 18.0] {
            coord.submit(&org, &JobRequest::sort(gb)).unwrap();
        }
        // now the model path must engage
        let o = coord.submit(&org, &JobRequest::sort(15.0)).unwrap();
        assert!(o.model_used.is_some(), "model should be trained now");
        assert!(coord.metrics().retrains >= 1);
        assert!(o.predicted_runtime_s > 0.0);
    }

    #[test]
    fn shared_corpus_enables_first_submission_model() {
        let dir = require_artifacts!();
        let cloud = Cloud::aws_like();
        let repo = corpus_repo(&cloud, JobKind::Grep);
        let mut coord = Coordinator::new(cloud, &dir, 2).unwrap();
        let added = coord.share(&repo).unwrap();
        assert_eq!(added, 162);
        let org = Organization::new("new-org");
        let req = JobRequest::grep(15.0, 0.1).with_target_seconds(500.0);
        let o = coord.submit(&org, &req).unwrap();
        // the very first submission is model-served — the paper's pitch
        assert!(o.model_used.is_some());
        assert!(o.prediction_error_pct() < 60.0, "err {}", o.prediction_error_pct());
        // and the new org's run landed in the shared repo
        let repo_after = coord.repo(JobKind::Grep).unwrap();
        assert_eq!(repo_after.len(), 163);
        assert!(repo_after.organizations().contains("new-org"));
    }

    #[test]
    fn retrain_cadence_respected() {
        let dir = require_artifacts!();
        let cloud = Cloud::aws_like();
        let repo = corpus_repo(&cloud, JobKind::Sort);
        let mut coord = Coordinator::new(cloud, &dir, 3).unwrap();
        coord.retrain_every = 4;
        coord.share(&repo).unwrap();
        let org = Organization::new("o");
        for i in 0..9 {
            coord
                .submit(&org, &JobRequest::sort(10.0 + i as f64))
                .unwrap();
        }
        // initial train + retrains every 4 contributions: 1 + 2
        assert_eq!(coord.metrics().retrains, 3, "{:?}", coord.metrics());
    }

    #[test]
    fn metrics_accumulate() {
        let dir = require_artifacts!();
        let cloud = Cloud::aws_like();
        let repo = corpus_repo(&cloud, JobKind::Sort);
        let mut coord = Coordinator::new(cloud, &dir, 4).unwrap();
        coord.share(&repo).unwrap();
        let org = Organization::new("o");
        let req = JobRequest::sort(15.0).with_target_seconds(2000.0);
        let o = coord.submit(&org, &req).unwrap();
        assert!(o.met_target, "loose target should be met");
        let m = coord.metrics();
        assert_eq!(m.submissions, 1);
        assert_eq!(m.targets_given, 1);
        assert_eq!(m.targets_met, 1);
        assert!(m.total_cost_usd > 0.0);
        assert!(m.mean_prediction_error_pct().is_finite());
    }
}
