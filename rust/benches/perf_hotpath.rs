//! Bench: §Perf hot paths across all three layers.
//!
//! * L1/L2 via PJRT: kNN batch prediction (the Pallas distance kernel),
//!   optimistic prediction and training step.
//! * L3 native: the same kNN math in pure Rust (what PJRT batching buys),
//!   simulator throughput, configurator sweep, coordinator submit.
//!
//! Results land in target/bench_results.csv; EXPERIMENTS.md §Perf quotes
//! them before/after optimization.

use c3o::cloud::Cloud;
use c3o::models::native::NativeKnn;
use c3o::models::{ConfigQuery, ModelKind, Predictor, RuntimeModel};
use c3o::runtime::Runtime;
use c3o::sim::{SimConfig, Simulator};
use c3o::util::bench::{black_box, Bench};
use c3o::util::matrix::MatF32;
use c3o::util::rng::Pcg32;
use c3o::workloads::{ExperimentGrid, JobKind, JobSpec};

fn main() {
    let cloud = Cloud::aws_like();
    let mut b = Bench::new("perf_hotpath");

    // ---- L3: simulator ----------------------------------------------------
    let sim = Simulator::new(SimConfig::default());
    let m5 = cloud.machine("m5.xlarge").unwrap().clone();
    let sort_stages = JobSpec::sort(15.0).stages();
    let mut rng = Pcg32::new(1);
    b.run("l3_simulate_sort_run", || {
        black_box(sim.run(&m5, 6, &sort_stages, &mut rng).runtime_s)
    });
    let sgd_stages = JobSpec::sgd(30.0, 100).stages();
    b.run("l3_simulate_sgd_run", || {
        black_box(sim.run(&m5, 6, &sgd_stages, &mut rng).runtime_s)
    });

    // ---- L3: matrix kernel (native fallback workhorse) ---------------------
    let a = MatF32::from_vec(128, 128, (0..128 * 128).map(|i| (i % 7) as f32).collect());
    let c = MatF32::from_vec(128, 128, (0..128 * 128).map(|i| (i % 5) as f32).collect());
    b.run("l3_matmul_128x128", || black_box(a.matmul(&c).data[0]));

    // ---- L3: incremental feature cache (delta-aware retrain inputs) --------
    // What a steady-state retrain pays to assemble its training inputs:
    // from-scratch featurization of a ~400-row corpus vs. replaying a
    // one-record delta through the cache. The gap is the per-retrain
    // saving before any model math starts — and it scales with delta
    // size, not corpus size.
    {
        use c3o::models::native::NativeEngine;
        use c3o::models::ModelTrainer;
        use c3o::repo::{FeatureMatrixCache, Featurizer, RuntimeDataRepo, RuntimeRecord};

        let featurizer = Featurizer::new(&cloud);
        let mut repo = RuntimeDataRepo::new(JobKind::Grep);
        let machines = ["c5.xlarge", "m5.xlarge", "r5.xlarge"];
        for k in 0..400usize {
            repo.contribute(RuntimeRecord {
                job: JobKind::Grep,
                org: format!("org-{}", k % 5),
                machine: machines[k % 3].to_string(),
                scaleout: 2 + (k % 11) as u32,
                job_features: vec![5.0 + k as f64 * 0.1, 0.01 + (k % 50) as f64 * 0.002],
                runtime_s: 50.0 + ((k * 31) % 997) as f64,
            })
            .unwrap();
        }
        b.run("l3_featurize_400_rows_scratch", || {
            black_box(featurizer.fit(&repo).2.len())
        });

        let mut cache = FeatureMatrixCache::new();
        cache.refresh(&featurizer, &repo);
        // per iteration: one conflict-replacement delta (a re-measurement
        // that wins the merge) replayed into the cache, then a cached fit
        let template = repo.records()[0].clone();
        let mut runtime = template.runtime_s;
        b.run("l3_featurize_1_row_delta_cached", || {
            runtime *= 0.999_999; // smaller runtime always wins the merge
            let mut peer = RuntimeDataRepo::new(JobKind::Grep);
            let mut r = template.clone();
            r.runtime_s = runtime;
            peer.contribute(r).unwrap();
            repo.merge(&peer).unwrap();
            let reused = cache.refresh(&featurizer, &repo);
            black_box(cache.fit(&repo).2.len() + reused)
        });

        // the same gap one layer up: a full kNN train (featurize + pad)
        // from scratch vs. consuming the warm cache
        let mut engine = NativeEngine::default();
        b.run("l3_knn_train_400_rows_scratch", || {
            black_box(
                engine
                    .train(&cloud, &repo, ModelKind::Pessimistic)
                    .unwrap()
                    .kind,
            )
        });
        b.run("l3_knn_train_400_rows_cached", || {
            black_box(
                engine
                    .train_cached(&cloud, &repo, ModelKind::Pessimistic, Some(&mut cache))
                    .unwrap()
                    .kind,
            )
        });

        // ---- L3: serial vs pooled cross-validated retrain ---------------
        // The PR-9 scenario: the full dynamic-selection retrain (both
        // model kinds × 3 CV folds on the 400-row corpus) run serially
        // and fanned through ComputePools of increasing width. The
        // decisions are bitwise-identical either way (property-tested in
        // tests/proptests.rs); this measures the wall-clock side of that
        // contract and emits BENCH_perf_hotpath.json for bench_trend.py.
        {
            use c3o::compute::ComputePool;
            use c3o::models::selection::{select_and_train, select_and_train_pooled};
            use c3o::util::json::Json;

            let mut cv_engine = NativeEngine::default();
            let serial = b
                .run("l3_cv_retrain_400_rows_serial", || {
                    black_box(
                        select_and_train(&mut cv_engine, &cloud, &repo, 3, 9)
                            .unwrap()
                            .1
                            .chosen,
                    )
                })
                .clone();
            let mut pooled = Vec::new();
            for threads in [2usize, 4, 8] {
                let pool = ComputePool::new(threads);
                let r = b
                    .run(&format!("l3_cv_retrain_400_rows_pool{threads}"), || {
                        black_box(
                            select_and_train_pooled(
                                &mut cv_engine,
                                &cloud,
                                &repo,
                                3,
                                9,
                                None,
                                Some(&pool),
                            )
                            .unwrap()
                            .1
                            .chosen,
                        )
                    })
                    .clone();
                pooled.push((threads, r.mean_ns));
            }
            let pool4_mean = pooled
                .iter()
                .find(|&&(t, _)| t == 4)
                .map(|&(_, ns)| ns)
                .unwrap_or(f64::INFINITY);
            let speedup4 = serial.mean_ns / pool4_mean;
            println!("cv retrain speedup (4-thread pool vs serial): {speedup4:.2}x");
            if speedup4 < 2.0 {
                eprintln!(
                    "WARN: pooled CV retrain {speedup4:.2}x below the 2x goal — \
                     expected on machines with fewer than 4 free cores"
                );
            }
            let json = Json::obj(vec![
                ("bench", Json::Str("perf_hotpath".to_string())),
                (
                    "cv_retrain_400_rows",
                    Json::obj(vec![
                        ("rows", Json::Num(repo.len() as f64)),
                        ("folds", Json::Num(3.0)),
                        ("model_kinds", Json::Num(2.0)),
                        ("serial_mean_ns", Json::Num(serial.mean_ns)),
                        (
                            "pool",
                            Json::Arr(
                                pooled
                                    .iter()
                                    .map(|&(threads, mean_ns)| {
                                        Json::obj(vec![
                                            ("threads", Json::Num(threads as f64)),
                                            ("mean_ns", Json::Num(mean_ns)),
                                            (
                                                "speedup_vs_serial",
                                                Json::Num(serial.mean_ns / mean_ns),
                                            ),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                        ("speedup_pool4_vs_serial", Json::Num(speedup4)),
                    ]),
                ),
            ]);
            std::fs::write("BENCH_perf_hotpath.json", json.render() + "\n").unwrap();
            println!("wrote BENCH_perf_hotpath.json");
        }
    }

    // ---- PJRT layers --------------------------------------------------------
    let dir = Runtime::default_dir();
    if !Runtime::artifacts_available(&dir) {
        eprintln!("SKIP PJRT cases: artifacts not built");
        b.finish();
        return;
    }
    let mut predictor = Predictor::new(&dir).unwrap();

    // corpus + trained models
    let grid = ExperimentGrid {
        experiments: ExperimentGrid::paper_table1()
            .experiments
            .into_iter()
            .filter(|e| e.spec.kind() == JobKind::Grep)
            .collect(),
        repetitions: 3,
    };
    let repo = grid.execute(&cloud, 42).repo_for(JobKind::Grep);
    let knn_model = predictor.train(&cloud, &repo, ModelKind::Pessimistic).unwrap();
    let opt_model = predictor.train(&cloud, &repo, ModelKind::Optimistic).unwrap();

    let queries: Vec<ConfigQuery> = (0..64)
        .map(|i| ConfigQuery {
            machine: ["c5.xlarge", "m5.xlarge", "r5.xlarge"][i % 3].to_string(),
            scaleout: 2 + (i as u32 % 11),
            job_features: vec![10.0 + (i as f64) * 0.15, 0.05 + 0.004 * i as f64],
        })
        .collect();

    b.run("l1_knn_predict_64q_pjrt", || {
        black_box(predictor.predict(&knn_model, &cloud, &queries).unwrap()[0])
    });
    b.run("l2_opt_predict_64q_pjrt", || {
        black_box(predictor.predict(&opt_model, &cloud, &queries).unwrap()[0])
    });

    // native comparison (same k, same data)
    let mut native = NativeKnn::fit(&cloud, &repo, 5).unwrap();
    b.run("l3_knn_predict_64q_native", || {
        black_box(native.predict(&cloud, &queries).unwrap()[0])
    });

    // training-step throughput
    b.run("l2_opt_train_full_fit", || {
        black_box(
            predictor
                .train(&cloud, &repo, ModelKind::Optimistic)
                .unwrap()
                .kind,
        )
    });

    // configurator decision (model inference over the whole grid)
    let configurator = c3o::configurator::Configurator::new(&cloud).with_machines(vec![
        "c5.xlarge".into(),
        "m5.xlarge".into(),
        "r5.xlarge".into(),
    ]);
    let req = c3o::configurator::JobRequest::grep(15.0, 0.1).with_target_seconds(300.0);
    let mut bound = c3o::models::BoundModel {
        predictor: &mut predictor,
        model: knn_model.clone(),
    };
    b.run("l3_configure_33_candidates", || {
        black_box(
            configurator
                .configure(&mut bound, &req)
                .unwrap()
                .unwrap()
                .node_count,
        )
    });

    b.finish();
}
