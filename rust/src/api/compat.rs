//! Legacy (v2) federation compatibility, quarantined.
//!
//! API v2 spoke an org-granular, *holdings*-based exchange: watermarks
//! were `(count, digest)` summaries of each org's held records
//! ([`OrgWatermarkV2`]), and a delta shipped every record of every org
//! whose summary differed — O(org corpus) per changed org. v3 replaced
//! it with record-level op-log deltas, and v4 layered mesh membership
//! and truncation on top; the v2 shapes survive only for peers that
//! predate the op log.
//!
//! This module is the one place that still knows how v2 works. Core
//! serve paths (shards, the sequential coordinator's v3+ arms) never
//! see a v2 request: deployments route `WatermarksV2`/`SyncPullV2`/
//! `SyncPushV2` to [`serve`], which translates them onto the three
//! narrow primitives of [`V2Host`]. A v2 *push* is translated onto the
//! current op log by appending each applied record with a fresh local
//! seqno — which may mark the org's log divergent from its home org's,
//! degrading later v3+ exchanges for that org to whole-org ships:
//! exactly the cost v2 always paid. A v2 *pull* against a truncated
//! (floored) log is naturally safe: holdings summaries never reference
//! folded history, so a differing org ships in full — the same
//! whole-org fallback v4 peers get via
//! [`OrgSnapshot`](crate::repo::OrgSnapshot) adoption.

use super::{ApiError, Request, Response, SyncReport};
use crate::repo::{OrgWatermarkV2, RuntimeRecord};
use crate::workloads::JobKind;
use std::collections::BTreeMap;

/// Legacy (v2) holdings watermarks for a job repository.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WatermarkSetV2 {
    pub job: JobKind,
    /// Repository generation the marks were read at.
    pub generation: u64,
    pub watermarks: BTreeMap<String, OrgWatermarkV2>,
}

/// A legacy (v2) org-granular delta: bare records of every org whose
/// holdings watermark differed, plus the responder's own v2 marks.
#[derive(Debug, Clone)]
pub struct SyncDeltaV2 {
    pub job: JobKind,
    /// Responder's repository generation at extraction time.
    pub generation: u64,
    /// Records of every org whose watermark differed.
    pub records: Vec<RuntimeRecord>,
    /// The responder's own v2 watermarks.
    pub watermarks: BTreeMap<String, OrgWatermarkV2>,
}

/// The three primitives a deployment must expose for [`serve`] to
/// answer v2 requests on its behalf. Deliberately narrow: hosts hand
/// over holdings summaries, org-granular extraction, and bare-record
/// application — everything protocol-shaped (request routing, response
/// pairing, error classes) stays here.
pub trait V2Host {
    /// Holdings watermarks of `job`'s repository.
    fn v2_watermarks(&mut self, job: JobKind) -> Result<WatermarkSetV2, ApiError>;

    /// Org-granular delta against a peer's holdings marks.
    fn v2_delta(
        &mut self,
        job: JobKind,
        theirs: &BTreeMap<String, OrgWatermarkV2>,
    ) -> Result<SyncDeltaV2, ApiError>;

    /// Apply bare records (no seqnos) through the usual merge + model
    /// refresh path.
    fn v2_apply(
        &mut self,
        job: JobKind,
        records: Vec<RuntimeRecord>,
    ) -> Result<SyncReport, ApiError>;
}

/// Answer one legacy (v2) request against `host`. Deployments route
/// exactly their `WatermarksV2`/`SyncPullV2`/`SyncPushV2` arms here;
/// any other request is a routing bug and comes back as
/// [`ApiError::Protocol`].
pub fn serve<H: V2Host + ?Sized>(host: &mut H, request: Request) -> Result<Response, ApiError> {
    match request {
        Request::WatermarksV2 { job } => host.v2_watermarks(job).map(Response::WatermarksV2),
        Request::SyncPullV2 { job, watermarks } => {
            host.v2_delta(job, &watermarks).map(Response::SyncDeltaV2)
        }
        Request::SyncPushV2 { job, records } => {
            host.v2_apply(job, records).map(Response::SyncApplied)
        }
        other => Err(ApiError::Protocol(format!(
            "non-v2 request routed to the compat adapter: {other:?}"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo::RuntimeDataRepo;
    use crate::workloads::JobKind;

    /// The minimal honest host: one repository, primitives wired
    /// straight to the repo-level v2 methods — the same calls every
    /// real deployment makes under its locks.
    struct RepoHost {
        repo: RuntimeDataRepo,
    }

    impl RepoHost {
        fn check(&self, job: JobKind) -> Result<(), ApiError> {
            if job == self.repo.job() {
                Ok(())
            } else {
                Err(ApiError::InvalidRequest(format!(
                    "host serves {}, not {}",
                    self.repo.job().name(),
                    job.name()
                )))
            }
        }
    }

    impl V2Host for RepoHost {
        fn v2_watermarks(&mut self, job: JobKind) -> Result<WatermarkSetV2, ApiError> {
            self.check(job)?;
            Ok(WatermarkSetV2 {
                job,
                generation: self.repo.generation(),
                watermarks: self.repo.watermarks_v2(),
            })
        }

        fn v2_delta(
            &mut self,
            job: JobKind,
            theirs: &BTreeMap<String, OrgWatermarkV2>,
        ) -> Result<SyncDeltaV2, ApiError> {
            self.check(job)?;
            Ok(SyncDeltaV2 {
                job,
                generation: self.repo.generation(),
                records: self.repo.delta_for_v2(theirs),
                watermarks: self.repo.watermarks_v2(),
            })
        }

        fn v2_apply(
            &mut self,
            job: JobKind,
            records: Vec<RuntimeRecord>,
        ) -> Result<SyncReport, ApiError> {
            self.check(job)?;
            let offered = records.len();
            let out = self
                .repo
                .merge_records(&records)
                .map_err(ApiError::InvalidRequest)?;
            self.repo.canonicalize();
            Ok(SyncReport::tally(
                job,
                offered,
                out.added,
                out.replaced,
                out.conflicts,
                &out.logged,
                self.repo.generation(),
            ))
        }
    }

    fn rec(org: &str, scaleout: u32, runtime: f64) -> RuntimeRecord {
        RuntimeRecord {
            job: JobKind::Sort,
            org: org.into(),
            machine: "m5.xlarge".into(),
            scaleout,
            job_features: vec![10.0],
            runtime_s: runtime,
        }
    }

    #[test]
    fn v2_requests_route_through_the_adapter() {
        let mut repo = RuntimeDataRepo::new(JobKind::Sort);
        repo.contribute(rec("a", 4, 100.0)).unwrap();
        repo.contribute(rec("a", 8, 60.0)).unwrap();
        let mut host = RepoHost { repo };

        let marks = match serve(&mut host, Request::WatermarksV2 { job: JobKind::Sort }) {
            Ok(Response::WatermarksV2(set)) => set,
            other => panic!("wrong response: {other:?}"),
        };
        assert_eq!(marks.watermarks["a"].count, 2);

        // a fresh peer pulls: every record of the differing org ships
        let delta = match serve(
            &mut host,
            Request::SyncPullV2 {
                job: JobKind::Sort,
                watermarks: BTreeMap::new(),
            },
        ) {
            Ok(Response::SyncDeltaV2(delta)) => delta,
            other => panic!("wrong response: {other:?}"),
        };
        assert_eq!(delta.records.len(), 2);

        // pushing them back is a no-op (idempotent holdings merge)
        let report = match serve(
            &mut host,
            Request::SyncPushV2 {
                job: JobKind::Sort,
                records: delta.records,
            },
        ) {
            Ok(Response::SyncApplied(report)) => report,
            other => panic!("wrong response: {other:?}"),
        };
        assert_eq!(report.changed(), 0);
        assert_eq!(report.skipped, 2);
    }

    #[test]
    fn non_v2_requests_are_a_protocol_error() {
        let mut host = RepoHost {
            repo: RuntimeDataRepo::new(JobKind::Sort),
        };
        match serve(&mut host, Request::Metrics) {
            Err(ApiError::Protocol(msg)) => assert!(msg.contains("compat"), "{msg}"),
            other => panic!("expected a protocol error, got {other:?}"),
        }
    }
}
