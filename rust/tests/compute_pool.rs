//! Concurrency stress tests of the shared [`ComputePool`] — the TSan CI
//! target for the PR-9 fan-out paths. Many caller threads hammer one
//! pool at once; every call must come back in task-index order with the
//! full permit budget restored, regardless of how callers interleave.

use c3o::compute::ComputePool;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

#[test]
fn many_concurrent_callers_share_one_pool_without_interference() {
    // More caller threads than permits: callers race for the permit
    // budget, some fan out, some fall back to inline serial execution —
    // and every single call must still return its own results, ordered.
    let pool = Arc::new(ComputePool::new(4));
    const CALLERS: usize = 16;
    const ROUNDS: usize = 20;
    const TASKS: usize = 24;

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for caller in 0..CALLERS {
            let pool = Arc::clone(&pool);
            handles.push(scope.spawn(move || {
                for round in 0..ROUNDS {
                    let base = caller * 1_000_000 + round * 1_000;
                    let tasks: Vec<_> =
                        (0..TASKS).map(|i| move || base + i * 7).collect();
                    let out = pool.map_ordered(tasks);
                    let expected: Vec<usize> =
                        (0..TASKS).map(|i| base + i * 7).collect();
                    assert_eq!(
                        out, expected,
                        "caller {caller} round {round}: results out of order \
                         or cross-contaminated"
                    );
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });

    // after the storm, the full permit budget is back: a fresh call can
    // still fan out and still reports helper wait time when it does
    let tasks: Vec<_> = (0..64usize).map(|i| move || i * i).collect();
    let (out, _wait) = pool.map_ordered_timed(tasks);
    assert_eq!(out, (0..64usize).map(|i| i * i).collect::<Vec<_>>());
}

#[test]
fn every_task_runs_exactly_once_under_contention() {
    let pool = Arc::new(ComputePool::new(3));
    let runs = Arc::new(AtomicUsize::new(0));
    const CALLERS: usize = 8;
    const TASKS: usize = 50;

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..CALLERS {
            let pool = Arc::clone(&pool);
            let runs = Arc::clone(&runs);
            handles.push(scope.spawn(move || {
                let tasks: Vec<_> = (0..TASKS)
                    .map(|i| {
                        let runs = Arc::clone(&runs);
                        move || {
                            runs.fetch_add(1, Ordering::Relaxed);
                            i
                        }
                    })
                    .collect();
                let out = pool.map_ordered(tasks);
                assert_eq!(out, (0..TASKS).collect::<Vec<_>>());
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });
    assert_eq!(runs.load(Ordering::Relaxed), CALLERS * TASKS);
}

#[test]
fn float_reduction_stays_bitwise_stable_under_contention() {
    // The determinism contract under concurrency: concurrent callers
    // folding their ordered results must all get the same bits as the
    // serial reduction, every time.
    let vals: Vec<f64> = (0..200).map(|i| 1.0 / (i as f64 + 2.5)).collect();
    let serial: f64 = vals.iter().sum();
    let pool = Arc::new(ComputePool::new(4));

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for caller in 0..12usize {
            let pool = Arc::clone(&pool);
            let vals = &vals;
            handles.push(scope.spawn(move || {
                for _ in 0..10 {
                    let tasks: Vec<_> = vals.iter().map(|&v| move || v).collect();
                    let out = pool.map_ordered(tasks);
                    let parallel: f64 = out.iter().sum();
                    assert_eq!(
                        serial.to_bits(),
                        parallel.to_bits(),
                        "caller {caller}: contended fold changed bits"
                    );
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });
}
