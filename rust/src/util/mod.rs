//! Self-contained utility layer.
//!
//! The offline build environment ships only the `xla` crate and its
//! transitive dependencies, so everything that would normally come from
//! `rand`, `serde`, `criterion`, or `proptest` is implemented here:
//!
//! * [`rng`] — a deterministic PCG32 generator (the corpus, the simulator's
//!   variance model, and all property tests are seeded and reproducible).
//! * [`stats`] — medians, quantiles, means, linear regression, MAPE/SMAPE.
//! * [`csv`] — minimal CSV reading/writing for the runtime-data repository.
//! * [`hash`] — stable FNV-1a hashing for WAL checksums and org digests.
//! * [`json`] — minimal JSON writer for metrics/figure exports.
//! * [`bench`] — a tiny criterion-style harness used by the
//!   `harness = false` bench binaries (warmup, timed iterations,
//!   percentile reporting).
//! * [`prop`] — a miniature property-testing driver (seeded case
//!   generation + first-failure minimization by case index).
//! * [`matrix`] — dense row-major f32/f64 matrices used by the native
//!   model fallbacks and the PJRT bridge.
//! * [`sync`] — poison-tolerant `Mutex`/`RwLock` acquisition for the
//!   panic-free serving path (see `rust/lint`).

pub mod bench;
pub mod csv;
pub mod hash;
pub mod json;
pub mod matrix;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
