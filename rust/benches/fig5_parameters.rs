//! Bench: regenerate Fig. 5 (influence of algorithm parameters on the
//! runtime — non-linear) and measure the sweep cost.

use c3o::cloud::Cloud;
use c3o::figures;
use c3o::util::bench::{black_box, Bench};

fn main() {
    let cloud = Cloud::aws_like();

    let fig = figures::fig5(&cloud, 42);
    println!("{}", fig.render());
    assert!(fig.all_claims_hold(), "Fig. 5 reproduction failed");

    let mut b = Bench::new("fig5_parameters");
    b.run("full_fig5_sweep", || {
        black_box(figures::fig5(&cloud, 42).table.rows.len())
    });
    b.finish();
}
