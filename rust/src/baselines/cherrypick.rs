//! CherryPick-style Bayesian optimization (Alipourfard et al., NSDI'17).
//!
//! Probes real configurations (through the metered oracle), models the
//! objective with a Gaussian process, and picks the next probe by
//! expected improvement, stopping when EI falls below a confidence
//! threshold or the probe budget is spent. The objective is log total
//! cost, with a multiplicative penalty for configurations that miss the
//! runtime target — matching CherryPick's constrained formulation.
//!
//! Every probe pays cluster time *plus the EMR-like provisioning delay*,
//! which is exactly the overhead the paper argues collaborative data
//! sharing avoids.

use crate::baselines::{metered_probe, ConfigSearch, SearchOutcome};
use crate::cloud::Cloud;
use crate::configurator::JobRequest;
use crate::models::oracle::SimOracle;
use crate::util::rng::Pcg32;
use crate::util::stats::solve_dense;
use anyhow::{anyhow, Result};

/// Standard normal CDF via the Abramowitz–Stegun erf approximation.
fn phi(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal PDF.
fn pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// A tiny RBF-kernel Gaussian process for the BO loop.
struct Gp {
    xs: Vec<Vec<f64>>,
    ys: Vec<f64>,
    lengthscale: f64,
    noise: f64,
}

impl Gp {
    fn new(lengthscale: f64, noise: f64) -> Self {
        Gp {
            xs: Vec::new(),
            ys: Vec::new(),
            lengthscale,
            noise,
        }
    }

    fn kernel(&self, a: &[f64], b: &[f64]) -> f64 {
        let d2: f64 = a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum();
        (-d2 / (2.0 * self.lengthscale * self.lengthscale)).exp()
    }

    fn observe(&mut self, x: Vec<f64>, y: f64) {
        self.xs.push(x);
        self.ys.push(y);
    }

    /// Posterior (mean, sd) at a point. O(n³) per call is fine: n ≤ 10.
    fn posterior(&self, x: &[f64]) -> (f64, f64) {
        let n = self.xs.len();
        if n == 0 {
            return (0.0, 1.0);
        }
        let ybar = self.ys.iter().sum::<f64>() / n as f64;
        // K + σ²I
        let mut k = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                k[i * n + j] = self.kernel(&self.xs[i], &self.xs[j]);
            }
            k[i * n + i] += self.noise;
        }
        // α = K⁻¹ (y - ȳ)
        let mut alpha: Vec<f64> = self.ys.iter().map(|y| y - ybar).collect();
        solve_dense(&mut k, &mut alpha, n);
        let kstar: Vec<f64> = self.xs.iter().map(|xi| self.kernel(xi, x)).collect();
        let mean = ybar + kstar.iter().zip(&alpha).map(|(a, b)| a * b).sum::<f64>();
        // var = k(x,x) - k*ᵀ K⁻¹ k*  (fresh solve for the variance term)
        let mut k2 = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                k2[i * n + j] = self.kernel(&self.xs[i], &self.xs[j]);
            }
            k2[i * n + i] += self.noise;
        }
        let mut v = kstar.clone();
        solve_dense(&mut k2, &mut v, n);
        let var = 1.0 - kstar.iter().zip(&v).map(|(a, b)| a * b).sum::<f64>();
        (mean, var.max(1e-9).sqrt())
    }
}

/// CherryPick configuration search.
#[derive(Debug, Clone)]
pub struct CherryPick {
    /// Total probe budget (seed + BO probes).
    pub max_probes: usize,
    /// Seed probes before the BO loop.
    pub seed_probes: usize,
    /// Stop when max EI drops below this.
    pub ei_threshold: f64,
    /// Average provisioning delay charged per probe, seconds.
    pub provisioning_s: f64,
    pub seed: u64,
}

impl Default for CherryPick {
    fn default() -> Self {
        CherryPick {
            max_probes: 9,
            seed_probes: 3,
            ei_threshold: 0.02,
            provisioning_s: 7.0 * 60.0,
            seed: 0xBEE5,
        }
    }
}

impl CherryPick {
    /// Normalized GP input for a configuration.
    fn encode(cloud: &Cloud, machine: &str, scaleout: u32) -> Vec<f64> {
        let m = cloud.machine(machine).expect("known machine");
        vec![
            m.vcpus as f64 / 8.0,
            m.memory_gib / 64.0,
            m.cpu_perf,
            scaleout as f64 / 12.0,
        ]
    }

    /// Objective: log total cost, penalized ×4 when the target is missed
    /// (CherryPick's constrained-objective trick).
    fn objective(cloud: &Cloud, request: &JobRequest, machine: &str, n: u32, runtime: f64) -> f64 {
        let cost = cloud.cost_usd(machine, n, runtime);
        let penalty = match request.target_s {
            Some(t) if runtime > t => 4.0,
            _ => 1.0,
        };
        (cost * penalty).ln()
    }
}

impl ConfigSearch for CherryPick {
    fn name(&self) -> &'static str {
        "cherrypick"
    }

    fn search(
        &mut self,
        cloud: &Cloud,
        oracle: &mut SimOracle,
        request: &JobRequest,
    ) -> Result<SearchOutcome> {
        let features = request.spec.job_features();
        let mut candidates: Vec<(String, u32)> = Vec::new();
        for m in cloud.machine_types() {
            for n in (2..=12).step_by(2) {
                candidates.push((m.name.clone(), n));
            }
        }
        if candidates.is_empty() {
            return Err(anyhow!("empty candidate grid"));
        }

        let mut rng = Pcg32::new(self.seed);
        let mut gp = Gp::new(0.5, 1e-4);
        let mut tried: Vec<usize> = Vec::new();
        let mut best: Option<(usize, f64, f64)> = None; // (cand idx, objective, runtime)
        let mut profiling_runs = 0u64;
        let mut profiling_cost = 0.0;
        let mut profiling_secs = 0.0;

        // seed probes: random distinct candidates, then the BO loop
        let seeds = rng.choose_indices(candidates.len(), self.seed_probes);
        let mut queue: Vec<usize> = seeds;
        loop {
            for i in queue.drain(..) {
                let (machine, n) = &candidates[i];
                let (runtime, cost, held) =
                    metered_probe(cloud, oracle, machine, *n, &features, self.provisioning_s)?;
                profiling_runs += 1;
                profiling_cost += cost;
                profiling_secs += held;
                let y = Self::objective(cloud, request, machine, *n, runtime);
                gp.observe(Self::encode(cloud, machine, *n), y);
                if best.map_or(true, |(_, by, _)| y < by) {
                    best = Some((i, y, runtime));
                }
                tried.push(i);
            }
            if tried.len() >= self.max_probes {
                break;
            }
            let (_, best_y, _) = best.expect("seeded");
            let mut next: Option<(usize, f64)> = None;
            for (i, (m, n)) in candidates.iter().enumerate() {
                if tried.contains(&i) {
                    continue;
                }
                let (mu, sd) = gp.posterior(&Self::encode(cloud, m, *n));
                let z = (best_y - mu) / sd;
                let ei = (best_y - mu) * phi(z) + sd * pdf(z);
                if next.map_or(true, |(_, be)| ei > be) {
                    next = Some((i, ei));
                }
            }
            let Some((i, ei)) = next else { break };
            if ei < self.ei_threshold {
                break; // confident enough
            }
            queue.push(i);
        }

        let (idx, _, runtime) = best.expect("at least one probe");
        let (machine, scaleout) = candidates[idx].clone();
        Ok(SearchOutcome {
            machine,
            scaleout,
            predicted_runtime_s: runtime,
            profiling_runs,
            profiling_cost_usd: profiling_cost,
            profiling_seconds: profiling_secs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::JobKind;

    #[test]
    fn erf_and_phi_sane() {
        assert!((erf(0.0)).abs() < 1e-9);
        assert!((erf(2.0) - 0.9953).abs() < 1e-3);
        assert!((phi(0.0) - 0.5).abs() < 1e-9);
        assert!(phi(3.0) > 0.99);
        assert!(phi(-3.0) < 0.01);
    }

    #[test]
    fn gp_interpolates_observations() {
        let mut gp = Gp::new(0.5, 1e-6);
        gp.observe(vec![0.0], 1.0);
        gp.observe(vec![1.0], 3.0);
        let (m0, s0) = gp.posterior(&[0.0]);
        assert!((m0 - 1.0).abs() < 1e-2, "{m0}");
        assert!(s0 < 0.1);
        // far away: reverts to prior mean with high sd
        let (mf, sf) = gp.posterior(&[10.0]);
        assert!((mf - 2.0).abs() < 0.2, "{mf}"); // prior mean = ȳ
        assert!(sf > 0.9);
    }

    #[test]
    fn cherrypick_stays_in_budget_and_meters_probes() {
        let cloud = Cloud::aws_like();
        let mut oracle = SimOracle::deterministic(JobKind::Sort, 3);
        let mut cp = CherryPick::default();
        let req = JobRequest::sort(15.0).with_target_seconds(600.0);
        let out = cp.search(&cloud, &mut oracle, &req).unwrap();
        assert!(out.profiling_runs <= cp.max_probes as u64);
        assert!(out.profiling_runs >= cp.seed_probes as u64);
        assert!(out.profiling_cost_usd > 0.0, "probes must cost money");
        assert!(out.profiling_seconds > out.profiling_runs as f64 * 7.0 * 60.0 * 0.9);
        assert!(cloud.machine(&out.machine).is_some());
        assert!((2..=12).contains(&out.scaleout));
    }

    #[test]
    fn cherrypick_finds_good_config_for_cpu_bound_job() {
        // With a deterministic oracle and 9 probes on a 54-point grid,
        // the chosen config's true cost should be within 2x of optimal.
        let cloud = Cloud::aws_like();
        let mut oracle = SimOracle::deterministic(JobKind::Sort, 3);
        let req = JobRequest::sort(15.0);
        let out = CherryPick::default().search(&cloud, &mut oracle, &req).unwrap();
        let mut check = SimOracle::deterministic(JobKind::Sort, 3);
        let q = crate::models::ConfigQuery {
            machine: out.machine.clone(),
            scaleout: out.scaleout,
            job_features: req.spec.job_features(),
        };
        let t = check.run_once(&cloud, &q).unwrap();
        let chosen_cost = cloud.cost_usd(&out.machine, out.scaleout, t);
        // true optimum over the same grid
        let mut best = f64::INFINITY;
        for m in cloud.machine_types() {
            for n in (2..=12).step_by(2) {
                let q = crate::models::ConfigQuery {
                    machine: m.name.clone(),
                    scaleout: n,
                    job_features: req.spec.job_features(),
                };
                let t = check.run_once(&cloud, &q).unwrap();
                best = best.min(cloud.cost_usd(&m.name, n, t));
            }
        }
        assert!(
            chosen_cost <= 2.0 * best,
            "chosen {chosen_cost} vs optimal {best}"
        );
    }
}
