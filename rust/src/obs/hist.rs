//! Mergeable log-bucketed latency histograms.
//!
//! A [`Histogram`] spreads nanosecond samples over fixed power-of-2
//! buckets: bucket 0 holds the value 0 and bucket `i` (for `i >= 1`)
//! holds `[2^(i-1), 2^i - 1]`, so the bucket of a sample is just
//! `64 - leading_zeros(ns)`. Everything is plain fixed-size `u64`
//! arrays — no maps, no floats in the bucketing path — so merging and
//! percentile extraction are deterministic regardless of fold order,
//! as the deterministic-zone lint rules require. Percentiles are
//! *exact given the bucketing*: the reported value is the inclusive
//! upper bound of the bucket holding the requested rank, capped at the
//! observed maximum.
//!
//! [`LatencyMatrix`] is the serving-side aggregate: one histogram per
//! (request kind × stage) cell, in fixed enum order, folded
//! worker-local exactly like `coordinator::Metrics`.

use super::{ReqKind, Stage};
use crate::util::json::Json;

/// Number of power-of-2 buckets. Bucket 39 tops out at `2^39 - 1` ns
/// (≈ 9.2 minutes); anything slower saturates into it.
pub const BUCKET_COUNT: usize = 40;

/// Bucket index of a nanosecond sample.
fn bucket_of(ns: u64) -> usize {
    if ns == 0 {
        0
    } else {
        ((64 - ns.leading_zeros()) as usize).min(BUCKET_COUNT - 1)
    }
}

/// Inclusive upper bound of a bucket, in nanoseconds.
fn bucket_upper_ns(idx: usize) -> u64 {
    if idx == 0 {
        0
    } else {
        (1u64 << idx) - 1
    }
}

/// A fixed-bucket latency histogram over nanosecond samples.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Histogram {
    counts: [u64; BUCKET_COUNT],
    total: u64,
    sum_ns: u64,
    max_ns: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: [0; BUCKET_COUNT],
            total: 0,
            sum_ns: 0,
            max_ns: 0,
        }
    }
}

impl Histogram {
    /// Record one sample.
    pub fn record(&mut self, ns: u64) {
        self.counts[bucket_of(ns)] += 1;
        self.total += 1;
        self.sum_ns = self.sum_ns.saturating_add(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    /// Fold another histogram into this one. Because buckets are fixed,
    /// a merge of any partition of a sample set equals the histogram of
    /// the whole set, bit for bit.
    pub fn merge(&mut self, other: &Histogram) {
        for (mine, theirs) in self.counts.iter_mut().zip(other.counts.iter()) {
            *mine += theirs;
        }
        self.total += other.total;
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn sum_ns(&self) -> u64 {
        self.sum_ns
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    pub fn mean_ns(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.sum_ns as f64 / self.total as f64
        }
    }

    /// The value at (at least) percentile `pct` (0..=100), as the
    /// inclusive upper bound of the bucket holding that rank, capped at
    /// the observed maximum. Integer math only; 0 for an empty
    /// histogram.
    pub fn percentile_ns(&self, pct: u32) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = (self.total * u64::from(pct)).div_ceil(100).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_upper_ns(i).min(self.max_ns);
            }
        }
        self.max_ns
    }

    /// JSON projection: count + p50/p95/p99/mean/max in microseconds.
    pub fn to_json(&self) -> Json {
        let us = |ns: u64| Json::Num(ns as f64 / 1000.0);
        Json::obj(vec![
            ("count", Json::Num(self.total as f64)),
            ("p50_us", us(self.percentile_ns(50))),
            ("p95_us", us(self.percentile_ns(95))),
            ("p99_us", us(self.percentile_ns(99))),
            ("mean_us", Json::Num(self.mean_ns() / 1000.0)),
            ("max_us", us(self.max_ns)),
        ])
    }
}

/// Per-(request kind × stage) histograms, fixed enum order. The
/// service folds drained traces in here; `merge` combines fold
/// partitions without order sensitivity.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LatencyMatrix {
    cells: [[Histogram; Stage::COUNT]; ReqKind::COUNT],
}

impl Default for LatencyMatrix {
    fn default() -> Self {
        LatencyMatrix {
            cells: [[Histogram::default(); Stage::COUNT]; ReqKind::COUNT],
        }
    }
}

impl LatencyMatrix {
    pub fn record(&mut self, kind: ReqKind, stage: Stage, ns: u64) {
        self.cells[kind.index()][stage.index()].record(ns);
    }

    pub fn merge(&mut self, other: &LatencyMatrix) {
        for (mine, theirs) in self.cells.iter_mut().zip(other.cells.iter()) {
            for (m, t) in mine.iter_mut().zip(theirs.iter()) {
                m.merge(t);
            }
        }
    }

    pub fn cell(&self, kind: ReqKind, stage: Stage) -> &Histogram {
        &self.cells[kind.index()][stage.index()]
    }

    /// Sum of recorded nanoseconds for one stage across every request
    /// kind (e.g. total featurize time regardless of what triggered the
    /// retrain).
    pub fn stage_sum_ns(&self, stage: Stage) -> u64 {
        ReqKind::ALL
            .iter()
            .map(|k| self.cell(*k, stage).sum_ns())
            .sum()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        ReqKind::ALL
            .iter()
            .all(|k| self.cell(*k, Stage::Total).count() == 0)
    }

    /// JSON projection (the `--json` `latency.kinds` block): one entry
    /// per request kind with end-to-end percentiles plus per-stage
    /// breakdowns; kinds and stages with zero samples are omitted, the
    /// rest appear in fixed enum order.
    pub fn to_json(&self) -> Json {
        let kinds: Vec<Json> = ReqKind::ALL
            .iter()
            .copied()
            .filter(|k| self.cell(*k, Stage::Total).count() > 0)
            .map(|k| {
                let stages: Vec<Json> = Stage::ALL
                    .iter()
                    .copied()
                    .filter(|s| *s != Stage::Total && self.cell(k, *s).count() > 0)
                    .map(|s| {
                        let mut fields =
                            vec![("stage".to_string(), Json::Str(s.name().to_string()))];
                        if let Json::Obj(kvs) = self.cell(k, s).to_json() {
                            fields.extend(kvs);
                        }
                        Json::Obj(fields)
                    })
                    .collect();
                Json::obj(vec![
                    ("kind", Json::Str(k.name().to_string())),
                    ("total", self.cell(k, Stage::Total).to_json()),
                    ("stages", Json::Arr(stages)),
                ])
            })
            .collect();
        Json::Arr(kinds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn buckets_are_powers_of_two() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), BUCKET_COUNT - 1);
        for i in 1..BUCKET_COUNT - 1 {
            // the upper bound of bucket i is the last value mapping to it
            assert_eq!(bucket_of(bucket_upper_ns(i)), i);
            assert_eq!(bucket_of(bucket_upper_ns(i) + 1), i + 1);
        }
    }

    #[test]
    fn percentiles_on_known_samples() {
        let mut h = Histogram::default();
        for ns in [10u64, 20, 30, 1000, 5_000_000] {
            h.record(ns);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.max_ns(), 5_000_000);
        // p100 is always the observed max
        assert_eq!(h.percentile_ns(100), 5_000_000);
        // p50 = rank 3 of 5 → the bucket of 30 ([16,31] → upper 31)
        assert_eq!(h.percentile_ns(50), 31);
        // empty histogram reports 0 everywhere
        assert_eq!(Histogram::default().percentile_ns(99), 0);
    }

    #[test]
    fn merge_of_splits_equals_whole() {
        // property: histogram over S == merge of histograms over any
        // partition of S, for pseudorandom samples and split points
        let mut rng = Pcg32::new(0xC30);
        for _ in 0..50 {
            let n = (rng.next_u64() % 200) as usize + 1;
            let samples: Vec<u64> = (0..n).map(|_| rng.next_u64() % (1 << 36)).collect();
            let split = (rng.next_u64() as usize) % (n + 1);
            let mut whole = Histogram::default();
            for &s in &samples {
                whole.record(s);
            }
            let mut left = Histogram::default();
            let mut right = Histogram::default();
            for &s in &samples[..split] {
                left.record(s);
            }
            for &s in &samples[split..] {
                right.record(s);
            }
            left.merge(&right);
            assert_eq!(left, whole, "merge of a split must equal the whole");
        }
    }

    #[test]
    fn percentiles_are_monotone_in_pct() {
        let mut rng = Pcg32::new(7);
        for _ in 0..20 {
            let mut h = Histogram::default();
            let n = (rng.next_u64() % 300) as usize + 1;
            for _ in 0..n {
                h.record(rng.next_u64() % (1 << 30));
            }
            let mut last = 0u64;
            for pct in 0..=100 {
                let v = h.percentile_ns(pct);
                assert!(v >= last, "p{pct} {v} < p{} {last}", pct - 1);
                assert!(v <= h.max_ns());
                last = v;
            }
        }
    }

    #[test]
    fn matrix_folds_like_metrics() {
        let mut a = LatencyMatrix::default();
        let mut b = LatencyMatrix::default();
        let mut whole = LatencyMatrix::default();
        for (i, ns) in [100u64, 2000, 35, 9_999_999].iter().enumerate() {
            let kind = ReqKind::ALL[i % ReqKind::COUNT];
            let stage = Stage::ALL[i % Stage::COUNT];
            whole.record(kind, stage, *ns);
            if i % 2 == 0 {
                a.record(kind, stage, *ns);
            } else {
                b.record(kind, stage, *ns);
            }
        }
        a.merge(&b);
        assert_eq!(a, whole);
        assert!(LatencyMatrix::default().is_empty());
    }
}
